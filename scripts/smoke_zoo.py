"""Dev driver: one fwd/loss + prefill/decode per smoke arch on CPU."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke_model

ARCH = sys.argv[1] if len(sys.argv) > 1 else None


def run(name):
    m = smoke_model(name)
    cfg = m.cfg
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.vlm_prefix_len:
        batch["img"] = jax.random.normal(key, (B, cfg.vlm_prefix_len, cfg.d_model),
                                         jnp.bfloat16)
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
    loss = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss)), (name, loss)

    # prefill + 3 decode steps
    logits, cache = jax.jit(lambda p, b: m.prefill(p, b, max_len=S + 8))(params, batch)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), name
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    step = jax.jit(m.decode_step)
    for _ in range(3):
        logits, cache = step(params, cache, tok)
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), name
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    print(f"OK {name}: loss={float(loss):.4f}")


for name in ([ARCH] if ARCH else ARCHS):
    run(name)
