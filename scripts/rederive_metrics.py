"""Re-derive roofline metrics from stored compiled-HLO artifacts.

The dry-run saves each cell's compiled HLO to experiments/hlo/*.hlo.gz, so
counter improvements re-derive flops/bytes/collectives WITHOUT recompiling:

  PYTHONPATH=src python scripts/rederive_metrics.py
"""
import glob
import gzip
import json
import os
import sys

sys.path.insert(0, "src")

from repro.core.hlo_counter import totals  # noqa: E402

HLO_DIR = "experiments/hlo"
JSON_DIR = "experiments/dryrun"


def main():
    n = 0
    for path in sorted(glob.glob(os.path.join(HLO_DIR, "*.hlo.gz"))):
        tag = os.path.basename(path)[:-len(".hlo.gz")]
        jpath = os.path.join(JSON_DIR, tag + ".json")
        if not os.path.exists(jpath):
            print("no json for", tag)
            continue
        with gzip.open(path, "rt") as f:
            txt = f.read()
        t = totals(txt)
        rec = json.load(open(jpath))
        rec["flops"] = t.flops
        rec["bytes_accessed"] = t.bytes
        rec["bytes_floor"] = t.bytes_floor
        rec["collective_bytes"] = dict(t.coll)
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1, default=str)
        n += 1
        print(f"rederived {tag}: flops={t.flops:.3e} floor={t.bytes_floor:.3e} "
              f"coll={t.coll.get('total', 0):.3e}")
    print(f"done: {n} cells")


if __name__ == "__main__":
    main()
