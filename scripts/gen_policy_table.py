"""Regenerate the README's CC-policy table from the live registry.

The table between the POLICY_TABLE markers in README.md is *generated*
(``repro.core.cc.policy_table_markdown``), and
``tests/test_policy_api.py::test_readme_policy_table_in_sync`` fails when
the two drift — run this script after changing any ``ParamSpec``:

    PYTHONPATH=src python scripts/gen_policy_table.py
"""
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.cc import policy_table_markdown  # noqa: E402

START = "<!-- POLICY_TABLE_START (generated; see scripts/gen_policy_table.py) -->"
END = "<!-- POLICY_TABLE_END -->"


def inject(readme_text: str) -> str:
    block = f"{START}\n{policy_table_markdown()}\n{END}"
    pattern = re.compile(re.escape(START) + ".*?" + re.escape(END), re.S)
    if not pattern.search(readme_text):
        raise SystemExit("README.md is missing the POLICY_TABLE markers")
    return pattern.sub(block, readme_text)


def main():
    path = os.path.join(os.path.dirname(__file__), "..", "README.md")
    with open(path) as f:
        text = f.read()
    new = inject(text)
    with open(path, "w") as f:
        f.write(new)
    print("README.md policy table regenerated"
          + (" (unchanged)" if new == text else ""))


if __name__ == "__main__":
    main()
