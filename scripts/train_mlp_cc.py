"""Train the learned ``mlp`` CC policy and ship its weights.

Runs the gradient-through-sim Adam loop (``repro.learn.train``) over the
default curriculum, then evaluates the trained policy against every
classical policy on the held-out ScenarioSpecs (topology scales and a
fault regime the curriculum never sees).

Artifacts:
  src/repro/learn/mlp_weights.json       the shipped trained weights
                                         (``cc.get_policy("mlp")`` loads
                                         these as the spec defaults)
  experiments/learn/training_curve.json  per-step loss/grad history
  experiments/learn/heldout_table.json   held-out comparison vs classical
  experiments/learn/checkpoint.json      resumable optimizer state

Usage:
  PYTHONPATH=src python scripts/train_mlp_cc.py [--steps N] [--lr F]
      [--seed N] [--resume] [--skip-heldout]
"""
import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.learn.train import (TrainConfig, curriculum_default,  # noqa: E402
                               heldout_default, heldout_eval, train)

OUT_DIR = os.path.join(ROOT, "experiments", "learn")
WEIGHTS_PATH = os.path.join(ROOT, "src", "repro", "learn",
                            "mlp_weights.json")
CKPT_PATH = os.path.join(OUT_DIR, "checkpoint.json")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--lr", type=float, default=0.08)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="continue from experiments/learn/checkpoint.json")
    ap.add_argument("--skip-heldout", action="store_true")
    args = ap.parse_args()

    os.makedirs(OUT_DIR, exist_ok=True)
    cfg = TrainConfig(steps=args.steps, lr=args.lr, seed=args.seed)
    curriculum = curriculum_default()
    print(f"curriculum: {[s.name for s, _ in curriculum]}", flush=True)

    t0 = time.time()
    res = train(cfg, curriculum=curriculum,
                resume=CKPT_PATH if args.resume else None,
                checkpoint_path=CKPT_PATH, verbose=True)
    wall = time.time() - t0
    print(f"trained {len(res.history)} steps in {wall:.0f}s: "
          f"loss {res.baseline_loss:.4f} -> {res.final_loss:.4f}",
          flush=True)

    meta = {"steps": len(res.history), "lr": args.lr, "seed": args.seed,
            "curriculum": [s.name for s, _ in curriculum],
            "baseline_loss": res.baseline_loss,
            "final_loss": res.final_loss,
            # cumulative across checkpoint resumes, not just this run
            "train_wall_s": res.wall_s}
    with open(WEIGHTS_PATH, "w") as f:
        json.dump({"weights": res.weights, "meta": meta}, f, indent=1)
    print(f"wrote {WEIGHTS_PATH}", flush=True)

    with open(os.path.join(OUT_DIR, "training_curve.json"), "w") as f:
        json.dump({"config": meta, "history": res.history,
                   "baselines": res.baselines}, f, indent=1)

    if args.skip_heldout:
        return

    print("held-out evaluation (unseen scales + gbn recovery)...",
          flush=True)
    ev = heldout_eval(specs=heldout_default(), cc_overrides=res.weights)
    ev["weights_meta"] = meta
    with open(os.path.join(OUT_DIR, "heldout_table.json"), "w") as f:
        json.dump(ev, f, indent=1)
    for r in ev["scenarios"]:
        print(f"  {r['scenario']:32s} mlp {r['completion_ms']['mlp']:8.3f}ms"
              f"  vs best({r['best_classical']}) {r['vs_best_pct']:+.1f}%"
              f"  vs worst({r['worst_classical']}) {r['vs_worst_pct']:+.1f}%",
              flush=True)
    print(f"all within 5% of best: {ev['all_within_5pct_of_best']}   "
          f"all beat worst: {ev['all_beat_worst']}", flush=True)


if __name__ == "__main__":
    main()
