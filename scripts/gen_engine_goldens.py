"""Regenerate tests/golden/engine_seed.json (engine equivalence goldens).

The stored file was produced by the PR-1 seed engine (per-hop scatter-add
loop, fixed 512-slot history ring, host-synced segment extends); the
rewritten engine must reproduce completion_time / t_finish / pause_count
for these scenarios within the tolerances in tests/test_engine_equiv.py.
Run this script only to re-baseline after an *intentional* physics change.
"""
import json
import os
import sys

import numpy as np

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, os.path.join(_ROOT, "tests"))

from _engine_scenarios import scenarios  # noqa: E402

from repro.core.cc import get_policy  # noqa: E402
from repro.core.engine import simulate  # noqa: E402


def main():
    out = {}
    for tag, topo, sched, pols, cfg in scenarios():
        for pol in pols:
            r = simulate(topo, sched, get_policy(pol), cfg)
            t_fin = np.asarray(r.t_finish, np.float64)
            out[f"{tag}/{pol}"] = {
                "finished": bool(r.finished),
                "completion_time": float(r.completion_time),
                "t_finish": [None if not np.isfinite(v) else float(v)
                             for v in t_fin],
                "pause_count": [float(v) for v in np.asarray(r.pause_count)],
                "delivered_sum": float(np.asarray(r.delivered).sum()),
                "cfg": {"dt": cfg.dt, "max_steps": cfg.max_steps,
                        "max_extends": cfg.max_extends},
            }
            print(tag, pol, "ct=", out[f"{tag}/{pol}"]["completion_time"],
                  flush=True)
    path = os.path.join(_ROOT, "tests", "golden", "engine_seed.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print("wrote", path)


if __name__ == "__main__":
    main()
