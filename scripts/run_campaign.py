#!/usr/bin/env python
"""CLI for resilient sweep campaigns (``repro.core.campaign``).

Runs a campaign with the durable chunk journal, retry ladder, lane
quarantine and deadline enforcement, and reports the manifest verdict.
The built-in ``--smoke`` campaign (a dcqcn CC sweep + a lossy-RoCE fault
sweep on a 4-GPU ring all-reduce) is shared with the crash/resume tests
and the CI kill/resume job:

    # run it, SIGKILL it after 3 journaled chunks, then resume:
    PYTHONPATH=src python scripts/run_campaign.py --smoke \\
        --chunk-lanes 4 --kill-after-chunks 3 || true
    PYTHONPATH=src python scripts/run_campaign.py --smoke \\
        --chunk-lanes 4 --resume --expect-full

``--kill-after-chunks N`` SIGKILLs the process right before dispatching
chunk N+1 — the crash-injection half of the kill/resume contract (the
journal then holds exactly N completed chunks).  ``--expect-full``
makes the exit code enforce complete coverage after a resume.

Exit codes: 0 = complete with full coverage; 2 = partial (failed chunks
or incomplete coverage); 3 = ``--expect-full`` violated; 4 = stopped by
deadline or chunk watchdog.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.campaign import run_campaign, smoke_tasks  # noqa: E402
from repro.core.sweep import SweepRunner  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.split("\n")[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--smoke", action="store_true",
                    help="run the built-in two-task smoke campaign")
    ap.add_argument("--name", default="smoke", help="campaign name")
    ap.add_argument("--out", default="experiments",
                    help="output root (journal + manifest live under "
                         "<out>/<name>/)")
    ap.add_argument("--resume", action="store_true",
                    help="replay journaled chunks of a previous run")
    ap.add_argument("--fresh", action="store_true",
                    help="discard an existing journal and restart")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="wall-clock budget in seconds; the campaign "
                         "checkpoints and exits when exceeded")
    ap.add_argument("--chunk-timeout", type=float, default=None,
                    metavar="S", help="per-chunk watchdog timeout")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="retry attempts per chunk beyond the first "
                         "(each takes one rung down the demotion ladder)")
    ap.add_argument("--backoff", type=float, default=0.5,
                    help="base retry backoff in seconds (doubles per "
                         "attempt)")
    ap.add_argument("--chunk-lanes", type=int, default=None,
                    help="lanes per journaled chunk (default: auto)")
    ap.add_argument("--no-quarantine", action="store_true",
                    help="skip the relaxed-budget retry of unhealthy "
                         "lanes")
    ap.add_argument("--kill-after-chunks", type=int, default=None,
                    metavar="N", help="SIGKILL self before dispatching "
                    "chunk N+1 (crash-injection for the resume test)")
    ap.add_argument("--expect-full", action="store_true",
                    help="exit 3 unless the campaign completed with "
                         "coverage 1.0")
    args = ap.parse_args(argv)

    if not args.smoke:
        ap.error("only --smoke campaigns are built in; drive custom "
                 "campaigns via repro.core.campaign.run_campaign "
                 "(benchmarks/atlas.py is the production example)")
    tasks, cfg = smoke_tasks()
    chunk_lanes = args.chunk_lanes or 4

    dispatched = {"n": 0}

    def hook(lo, hi, B):
        if (args.kill_after_chunks is not None
                and dispatched["n"] >= args.kill_after_chunks):
            print(f"[kill-injection] SIGKILL before dispatch "
                  f"{dispatched['n'] + 1}", flush=True)
            os.kill(os.getpid(), signal.SIGKILL)
        dispatched["n"] += 1

    runner = SweepRunner(cfg=cfg, chunk_lanes=chunk_lanes,
                         dispatch_hook=hook
                         if args.kill_after_chunks is not None else None)
    res = run_campaign(
        tasks, name=args.name, out_dir=args.out, runner=runner, cfg=cfg,
        chunk_lanes=chunk_lanes, resume=args.resume, fresh=args.fresh,
        max_retries=args.max_retries, backoff_s=args.backoff,
        deadline_s=args.deadline, chunk_timeout_s=args.chunk_timeout,
        quarantine=not args.no_quarantine,
        progress=lambda m: print(f"[campaign] {m}", flush=True))

    cov = float(res.manifest["coverage"])
    print(json.dumps({"campaign": res.name, "status": res.status,
                      "coverage": cov,
                      "wall_s": res.manifest["wall_s"],
                      "manifest": os.path.join(res.out_dir,
                                               "manifest.json")},
                     indent=1))
    if args.expect_full and not res.ok:
        print(f"--expect-full: FAILED (status={res.status}, "
              f"coverage={cov:.0%})", file=sys.stderr)
        return 3
    if res.status in ("deadline", "chunk_timeout"):
        return 4
    return 0 if res.ok else 2


if __name__ == "__main__":
    raise SystemExit(main())
