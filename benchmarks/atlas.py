"""Policy x tuned-param x fabric atlas slices through the campaign layer.

The regime atlas the ROADMAP calls for, one committed slice at a time:
for each CC policy, a key tuning parameter (spanned around its paper
default) is crossed with a fig-12-style fabric grid — paired ECN ramps
(kmin, 4*kmin) x PFC thresholds (xoff) — on the paper's CLOS topology,
every (policy, param, fabric) cell one lane of a sharded
``SweepRunner(mesh="auto")`` dispatch.  Emits one CSV row per cell plus a
JSON sidecar with the wall-clock/scaling record.

Since PR 10 the dispatch runs through ``repro.core.campaign``: every
chunk is journaled (atomic write under
``experiments/atlas/<campaign>/journal/``), so a killed run resumes with
``--resume`` instead of starting over, failed chunks degrade down the
retry ladder instead of aborting the slice, unhealthy lanes get one
relaxed-budget quarantine retry, and ``manifest.json`` records exactly
what the committed CSV covers.  The CSV/JSON schema is unchanged from
the pre-campaign atlas.

The learned policy rides the same axes: the ``mlp`` slice spans its
``out_gain`` (the target-tracking speed — 0.5x/1x/2x the trained
default) over the identical fabric grid, so the atlas directly answers
whether the trained policy's ranking survives fabric mistuning the way
the classical policies' rankings do.

Usage (the committed ``experiments/atlas/`` slice):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    REPRO_BENCH_SCALE=paper \\
    PYTHONPATH=src python benchmarks/atlas.py [--resume] [--deadline S]

``REPRO_BENCH_SCALE=small`` gives a CI-sized smoke of the same shape.
The workload is the topology-aware ring All-Reduce (tractable at 128
ranks on a single-core host, unlike the 1D algorithm's ~130k flows at
O(ranks^2)); completion times are end-of-collective, lane health is
recorded per cell (an 'exhausted'/'diverged' cell is a truncation
artifact, not a measurement).
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import time

import jax
import numpy as np

try:                             # run.py imports us as benchmarks.*;
    from benchmarks.common import SCALE, collective_size, paper_fabric
except ImportError:              # direct script run: sys.path[0]=benchmarks/
    from common import SCALE, collective_size, paper_fabric

from repro.common.cache import enable_compilation_cache
from repro.core.campaign import CampaignTask, run_campaign
from repro.core.cc import get_policy
from repro.core.collectives import allreduce_ring
from repro.core.engine import EngineConfig
from repro.core.sweep import BatchResults, SweepRunner

OUTDIR = os.environ.get("REPRO_ATLAS_OUT", "experiments/atlas")

# one key tunable per policy, spanned geometrically around the paper
# default (x0.5, x1, x2) — the Hoefler/Mittal sensitivity question in
# miniature: does the fabric-tuning ranking survive the policy's own
# tuning?  Defaults from the declared ParamSpec tables.
KEY_PARAM = {"dcqcn": "rai_frac", "hpcc": "eta", "timely": "beta",
             "mlp": "out_gain"}
PARAM_SPAN = (0.5, 1.0, 2.0)

# fig-12-style paired ECN ramps x PFC thresholds (not a kmin x kmax
# factorial, which would include inverted ramps)
FABRIC_PTS = [(k, 4.0 * k, x)
              for k in (100e3, 1000e3)
              for x in (0.25e6, 4e6)]


def atlas_cfg() -> EngineConfig:
    if SCALE == "small":
        return EngineConfig(dt=2e-6, max_steps=4000, max_extends=6,
                            queue_stride=0)
    return EngineConfig(dt=4e-6, max_steps=6000, max_extends=6,
                        queue_stride=0)


def _key_param_values(pol: str) -> list[float]:
    policy = get_policy(pol)
    spec = policy.param_spec(KEY_PARAM[pol])
    return [min(max(spec.default * s, spec.lo), spec.hi)
            for s in PARAM_SPAN]


def build_tasks(topo, sched) -> list[CampaignTask]:
    """One campaign task per policy: its key-param span x fabric grid."""
    tasks = []
    for pol in KEY_PARAM:
        key = KEY_PARAM[pol]
        lanes = [(v, f) for v in _key_param_values(pol)
                 for f in FABRIC_PTS]
        pts = np.asarray([f for _, f in lanes], np.float32)
        tasks.append(CampaignTask(
            pol, topo, sched, get_policy(pol),
            stacked_params={key: np.asarray([v for v, _ in lanes],
                                            np.float32)},
            stacked_fabric={"kmin": pts[:, 0], "kmax": pts[:, 1],
                            "xoff": pts[:, 2]}))
    return tasks


def policy_rows(pol: str, batch: BatchResults, wall_s: float) -> dict:
    """CSV rows + summary for one policy's merged slice (schema identical
    to the pre-campaign atlas)."""
    key = KEY_PARAM[pol]
    spec = get_policy(pol).param_spec(key)
    rows = []
    status = batch.lane_status()
    for i in range(batch.n):
        rows.append({
            "policy": pol, "param": key,
            "param_value": float(batch.params[key][i]),
            "param_rel_default": round(float(batch.params[key][i])
                                       / spec.default, 3),
            "kmin": float(batch.fabric["kmin"][i]),
            "kmax": float(batch.fabric["kmax"][i]),
            "xoff": float(batch.fabric["xoff"][i]),
            "completion_ms": round(float(batch.completion_time[i]) * 1e3, 4),
            "pfc_frames": int(batch.pause_count[i].sum()),
            "lane_status": status[i],
        })
    fin = batch.finished
    out = {"rows": rows, "wall_s": round(wall_s, 1), "n_lanes": batch.n,
           "n_unfinished": int((~fin).sum())}
    if fin.any():
        best = batch.best()
        out["best"] = {
            "completion_ms": round(
                float(batch.completion_time[best]) * 1e3, 4),
            "param_value": float(batch.params[key][best]),
            "kmin": float(batch.fabric["kmin"][best]),
            "xoff": float(batch.fabric["xoff"][best])}
        out["spread_pct"] = round(float(
            (batch.completion_time[fin].max()
             / batch.completion_time[fin].min() - 1) * 100), 2)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--resume", action="store_true",
                    help="replay journaled chunks of a killed run")
    ap.add_argument("--fresh", action="store_true",
                    help="discard an existing journal and restart")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="wall-clock budget; checkpoint-and-exit after")
    ap.add_argument("--chunk-timeout", type=float, default=None,
                    metavar="S", help="per-chunk watchdog timeout")
    ap.add_argument("--max-retries", type=int, default=3)
    ap.add_argument("--chunk-lanes", type=int, default=None,
                    help="lanes per journaled chunk (default: auto)")
    args = ap.parse_args(argv)

    enable_compilation_cache()
    fab = paper_fabric()
    topo = fab.build()
    # n_chunks=1: 2*(R-1)*R flows (~32.5k at 128 ranks) instead of 4x
    # that — the chunking controls pipelining depth, not bytes moved
    sched = allreduce_ring(topo, list(range(fab.n_gpus)), collective_size(),
                           n_chunks=1)
    cfg = atlas_cfg()
    runner = SweepRunner(cfg, mesh="auto",
                         chunk_lanes=args.chunk_lanes or "auto")
    n_dev = runner.n_mesh_devices
    print(f"atlas: scale={SCALE} gpus={fab.n_gpus} flows={sched.n_flows} "
          f"devices={n_dev} mesh={runner.mesh}")
    os.makedirs(OUTDIR, exist_ok=True)
    tag = f"{SCALE}_ring{fab.n_gpus}"
    t00 = time.time()
    res = run_campaign(build_tasks(topo, sched), name=f"atlas_{tag}",
                       out_dir=OUTDIR, runner=runner, cfg=cfg,
                       chunk_lanes=args.chunk_lanes,
                       resume=args.resume, fresh=args.fresh,
                       max_retries=args.max_retries,
                       deadline_s=args.deadline,
                       chunk_timeout_s=args.chunk_timeout,
                       progress=lambda m: print(f"  [campaign] {m}"))
    total = time.time() - t00
    all_rows, meta = [], {}
    for pol in KEY_PARAM:
        ts = res.manifest["tasks"].get(pol, {})
        wall = sum(c.get("wall_s", 0.0) for c in ts.get("chunks", ()))
        s = policy_rows(pol, res.results[pol], wall)
        all_rows += s["rows"]
        meta[pol] = {k: v for k, v in s.items() if k != "rows"}
        best = s.get("best", {}).get("completion_ms", "n/a")
        print(f"  {pol:8s} B={s['n_lanes']} wall {s['wall_s']}s "
              f"best {best}ms spread {s.get('spread_pct', 'n/a')}% "
              f"unfinished {s['n_unfinished']}")
    csv_path = os.path.join(OUTDIR, f"atlas_{tag}.csv")
    with open(csv_path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(all_rows[0]))
        w.writeheader()
        w.writerows(all_rows)
    side = {
        "scale": SCALE, "n_gpus": fab.n_gpus, "n_flows": sched.n_flows,
        "workload": f"allreduce_ring {collective_size()/1e6:.0f}MB",
        "cfg": {"dt": cfg.dt, "max_steps": cfg.max_steps,
                "max_extends": cfg.max_extends},
        "backend": jax.default_backend(), "devices": n_dev,
        "mesh_shape": ({runner.mesh.axis_names[0]: n_dev}
                       if runner.mesh is not None else None),
        "sharded": runner.mesh is not None,
        "total_wall_s": round(total, 1),
        "cells": len(all_rows),
        "per_policy": meta,
        "campaign": {
            "status": res.status,
            "coverage": res.manifest["coverage"],
            "fingerprint": res.manifest["fingerprint"],
            "manifest": os.path.join(res.out_dir, "manifest.json"),
            "demotions": sum(len(t["demotions"])
                             for t in res.manifest["tasks"].values()),
            "quarantined": {p: t["quarantine"]["lanes"]
                            for p, t in res.manifest["tasks"].items()
                            if t.get("quarantine")},
        },
        "note": "emulated host devices share one core: the sharded "
                "dispatch here validates placement/equivalence at paper "
                "scale, wall-clock parallel speedup needs real devices "
                "(BENCH_engine.json 'sharded' records measured "
                "efficiency)",
    }
    with open(os.path.join(OUTDIR, f"atlas_{tag}.json"), "w") as f:
        json.dump(side, f, indent=1)
    print(f"wrote {csv_path} ({len(all_rows)} cells) in {total:.0f}s "
          f"[campaign {res.status}, coverage "
          f"{res.manifest['coverage']:.0%}]")
    return 0 if res.ok else 2


if __name__ == "__main__":
    raise SystemExit(main())
