"""Policy x tuned-param x fabric atlas slices through the sharded path.

The regime atlas the ROADMAP calls for, one committed slice at a time:
for each CC policy, a key tuning parameter (spanned around its paper
default) is crossed with a fig-12-style fabric grid — paired ECN ramps
(kmin, 4*kmin) x PFC thresholds (xoff) — on the paper's CLOS topology,
every (policy, param, fabric) cell one lane of a sharded
``SweepRunner(mesh="auto")`` dispatch.  Emits one CSV row per cell plus a
JSON sidecar with the wall-clock/scaling record.

The learned policy rides the same axes: the ``mlp`` slice spans its
``out_gain`` (the target-tracking speed — 0.5x/1x/2x the trained
default) over the identical fabric grid, so the atlas directly answers
whether the trained policy's ranking survives fabric mistuning the way
the classical policies' rankings do.

Usage (the committed ``experiments/atlas/`` slice):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    REPRO_BENCH_SCALE=paper \\
    PYTHONPATH=src python benchmarks/atlas.py

``REPRO_BENCH_SCALE=small`` gives a CI-sized smoke of the same shape.
The workload is the topology-aware ring All-Reduce (tractable at 128
ranks on a single-core host, unlike the 1D algorithm's ~130k flows at
O(ranks^2)); completion times are end-of-collective, lane health is
recorded per cell (an 'exhausted'/'diverged' cell is a truncation
artifact, not a measurement).
"""
from __future__ import annotations

import csv
import json
import os
import time

import jax
import numpy as np

try:                             # run.py imports us as benchmarks.*;
    from benchmarks.common import SCALE, collective_size, paper_fabric
except ImportError:              # direct script run: sys.path[0]=benchmarks/
    from common import SCALE, collective_size, paper_fabric

from repro.common.cache import enable_compilation_cache
from repro.core.cc import get_policy
from repro.core.collectives import allreduce_ring
from repro.core.engine import EngineConfig
from repro.core.sweep import SweepRunner

OUTDIR = os.environ.get("REPRO_ATLAS_OUT", "experiments/atlas")

# one key tunable per policy, spanned geometrically around the paper
# default (x0.5, x1, x2) — the Hoefler/Mittal sensitivity question in
# miniature: does the fabric-tuning ranking survive the policy's own
# tuning?  Defaults from the declared ParamSpec tables.
KEY_PARAM = {"dcqcn": "rai_frac", "hpcc": "eta", "timely": "beta",
             "mlp": "out_gain"}
PARAM_SPAN = (0.5, 1.0, 2.0)

# fig-12-style paired ECN ramps x PFC thresholds (not a kmin x kmax
# factorial, which would include inverted ramps)
FABRIC_PTS = [(k, 4.0 * k, x)
              for k in (100e3, 1000e3)
              for x in (0.25e6, 4e6)]


def atlas_cfg() -> EngineConfig:
    if SCALE == "small":
        return EngineConfig(dt=2e-6, max_steps=4000, max_extends=6,
                            queue_stride=0)
    return EngineConfig(dt=4e-6, max_steps=6000, max_extends=6,
                        queue_stride=0)


def policy_slice(runner: SweepRunner, topo, sched, pol: str) -> dict:
    """One sharded dispatch: key-param span x fabric grid for ``pol``."""
    policy = get_policy(pol)
    key = KEY_PARAM[pol]
    spec = policy.param_spec(key)
    vals = [min(max(spec.default * s, spec.lo), spec.hi)
            for s in PARAM_SPAN]
    lanes = [(v, f) for v in vals for f in FABRIC_PTS]
    pts = np.asarray([f for _, f in lanes], np.float32)
    t0 = time.time()
    batch = runner.run_batch(
        topo, sched, policy,
        {key: np.asarray([v for v, _ in lanes], np.float32)},
        stacked_fabric={"kmin": pts[:, 0], "kmax": pts[:, 1],
                        "xoff": pts[:, 2]})
    wall = time.time() - t0
    rows = []
    status = batch.lane_status()
    for i in range(batch.n):
        rows.append({
            "policy": pol, "param": key,
            "param_value": float(batch.params[key][i]),
            "param_rel_default": round(float(batch.params[key][i])
                                       / spec.default, 3),
            "kmin": float(batch.fabric["kmin"][i]),
            "kmax": float(batch.fabric["kmax"][i]),
            "xoff": float(batch.fabric["xoff"][i]),
            "completion_ms": round(float(batch.completion_time[i]) * 1e3, 4),
            "pfc_frames": int(batch.pause_count[i].sum()),
            "lane_status": status[i],
        })
    fin = batch.finished
    out = {"rows": rows, "wall_s": round(wall, 1), "n_lanes": batch.n,
           "n_unfinished": int((~fin).sum())}
    if fin.any():
        best = batch.best()
        out["best"] = {
            "completion_ms": round(
                float(batch.completion_time[best]) * 1e3, 4),
            "param_value": float(batch.params[key][best]),
            "kmin": float(batch.fabric["kmin"][best]),
            "xoff": float(batch.fabric["xoff"][best])}
        out["spread_pct"] = round(float(
            (batch.completion_time[fin].max()
             / batch.completion_time[fin].min() - 1) * 100), 2)
    return out


def main():
    enable_compilation_cache()
    fab = paper_fabric()
    topo = fab.build()
    # n_chunks=1: 2*(R-1)*R flows (~32.5k at 128 ranks) instead of 4x
    # that — the chunking controls pipelining depth, not bytes moved
    sched = allreduce_ring(topo, list(range(fab.n_gpus)), collective_size(),
                           n_chunks=1)
    cfg = atlas_cfg()
    runner = SweepRunner(cfg, mesh="auto")
    n_dev = runner.n_mesh_devices
    print(f"atlas: scale={SCALE} gpus={fab.n_gpus} flows={sched.n_flows} "
          f"devices={n_dev} mesh={runner.mesh}")
    os.makedirs(OUTDIR, exist_ok=True)
    t00 = time.time()
    all_rows, meta = [], {}
    for pol in KEY_PARAM:
        s = policy_slice(runner, topo, sched, pol)
        all_rows += s["rows"]
        meta[pol] = {k: v for k, v in s.items() if k != "rows"}
        best = s.get("best", {}).get("completion_ms", "n/a")
        print(f"  {pol:8s} B={s['n_lanes']} wall {s['wall_s']}s "
              f"best {best}ms spread {s.get('spread_pct', 'n/a')}% "
              f"unfinished {s['n_unfinished']}")
    total = time.time() - t00
    tag = f"{SCALE}_ring{fab.n_gpus}"
    csv_path = os.path.join(OUTDIR, f"atlas_{tag}.csv")
    with open(csv_path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(all_rows[0]))
        w.writeheader()
        w.writerows(all_rows)
    side = {
        "scale": SCALE, "n_gpus": fab.n_gpus, "n_flows": sched.n_flows,
        "workload": f"allreduce_ring {collective_size()/1e6:.0f}MB",
        "cfg": {"dt": cfg.dt, "max_steps": cfg.max_steps,
                "max_extends": cfg.max_extends},
        "backend": jax.default_backend(), "devices": n_dev,
        "mesh_shape": ({runner.mesh.axis_names[0]: n_dev}
                       if runner.mesh is not None else None),
        "sharded": runner.mesh is not None,
        "total_wall_s": round(total, 1),
        "cells": len(all_rows),
        "per_policy": meta,
        "note": "emulated host devices share one core: the sharded "
                "dispatch here validates placement/equivalence at paper "
                "scale, wall-clock parallel speedup needs real devices "
                "(BENCH_engine.json 'sharded' records measured "
                "efficiency)",
    }
    with open(os.path.join(OUTDIR, f"atlas_{tag}.json"), "w") as f:
        json.dump(side, f, indent=1)
    print(f"wrote {csv_path} ({len(all_rows)} cells) in {total:.0f}s")


if __name__ == "__main__":
    main()
