"""One function per paper figure/table (paper Figs 3-10 + beyond-paper).

Each returns CSV rows (figure,metric,...,value) and saves raw series to
experiments/bench/*.json for inspection.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (RUNNER, collective_size, downsample, emit,
                               engine_cfg, paper_clos, run_cached, save_json)
from repro.core.cc import ALL_POLICIES, get_policy
from repro.core.collectives import allreduce_1d, allreduce_2d, alltoall, incast
from repro.core.engine import EngineConfig
from repro.core.topology import single_switch
from repro.core.workload import (DLRMCommSpec, DLRMComputeProfile,
                                 simulate_dlrm_iteration)


def fig3_incast():
    """Fig 3: queue-length timeline + completion for 7->1 incast."""
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 10e6)
    cfg = EngineConfig(dt=1e-6, max_steps=2000, max_extends=6)
    rows, series = [], {}
    for pol in ALL_POLICIES:
        r = run_cached("incast", topo, sched, pol, cfg)
        q = r.dev_queue[:, 8]
        rows.append(("fig3", "completion_ms", pol, round(r.completion_time * 1e3, 4)))
        rows.append(("fig3", "max_queue_mb", pol, round(float(q.max()) / 1e6, 3)))
        rows.append(("fig3", "pfc_frames", pol, int(r.pause_count.sum())))
        series[pol] = downsample(q)
    save_json("fig3_queue_timelines.json", series)
    return rows


def fig4_single_switch_collectives():
    """Fig 4: single-switch All-Reduce / All-To-All show no congestion."""
    n = 8
    topo = single_switch(n)
    size = 10e6
    cfg = EngineConfig(dt=1e-6, max_steps=3000, max_extends=6)
    rows, series = [], {}
    for name, sched in (("alltoall", alltoall(topo, list(range(n)), size)),
                        ("allreduce", allreduce_1d(topo, list(range(n)), size))):
        for pol in ("pfc", "dcqcn", "dctcp", "timely", "hpcc"):
            r = run_cached(f"ss_{name}", topo, sched, pol, cfg)
            q = r.dev_queue[:, n]  # the switch
            rows.append(("fig4", f"{name}_completion_ms", pol,
                         round(r.completion_time * 1e3, 4)))
            rows.append(("fig4", f"{name}_max_queue_mb", pol,
                         round(float(q.max()) / 1e6, 3)))
            rows.append(("fig4", f"{name}_pfc_frames", pol, int(r.pause_count.sum())))
            series[f"{name}_{pol}"] = downsample(q)
    save_json("fig4_queue_timelines.json", series)
    return rows


def fig5_7_clos_queues():
    """Figs 5/6/7: ToR vs Spine queue timelines + ECMP imbalance (A2A)."""
    topo, n = paper_clos()
    sched = alltoall(topo, list(range(n)), collective_size())
    cfg = engine_cfg()
    rows, series = [], {}
    tor = topo.meta["tor_devs"]
    spine = topo.meta["spine_devs"]
    for pol in ALL_POLICIES:
        r = run_cached("clos_a2a", topo, sched, pol, cfg)
        tq = r.dev_queue[:, tor]
        sq = r.dev_queue[:, spine]
        rows.append(("fig6", "tor_max_queue_mb", pol, round(float(tq.max()) / 1e6, 3)))
        rows.append(("fig7", "spine_max_queue_mb", pol, round(float(sq.max()) / 1e6, 3)))
        if pol == "pfc":
            # Fig 5: per-spine imbalance under ECMP
            peaks = sq.max(axis=0)
            rows.append(("fig5", "spine_peak_imbalance", pol,
                         round(float(peaks.max() / max(peaks.min(), 1.0)), 2)))
            series["spines_pfc"] = [downsample(sq[:, i]) for i in range(min(3, sq.shape[1]))]
        series[f"tor_{pol}"] = downsample(tq.sum(axis=1))
        series[f"spine_{pol}"] = downsample(sq.sum(axis=1))
    save_json("fig5_7_queue_timelines.json", series)
    return rows


def fig8_completion():
    """Fig 8: completion time of 1D/2D All-Reduce + All-To-All per CC."""
    topo, n = paper_clos()
    size = collective_size()
    cfg = engine_cfg(queue_stride=0)   # no timeline consumed
    rows = []
    scheds = {
        "ar_1d": allreduce_1d(topo, list(range(n)), size),
        "ar_2d": allreduce_2d(topo, list(range(n)), size),
        "a2a": alltoall(topo, list(range(n)), size),
    }
    for name, sched in scheds.items():
        for pol in ALL_POLICIES:
            r = run_cached(f"clos_{name}" if name != "a2a" else "clos_a2a",
                           topo, sched, pol, cfg)
            rows.append(("fig8", f"{name}_completion_ms", pol,
                         round(r.completion_time * 1e3, 4)))
            if not r.finished:
                rows.append(("fig8", f"{name}_UNFINISHED", pol, 1))
    return rows


def fig9_pfc_counts():
    """Fig 9: PAUSE-frame counts per workload per CC."""
    topo, n = paper_clos()
    size = collective_size()
    cfg = engine_cfg(queue_stride=0)
    rows = []
    scheds = {
        "ar_1d": ("clos_ar_1d", allreduce_1d(topo, list(range(n)), size)),
        "ar_2d": ("clos_ar_2d", allreduce_2d(topo, list(range(n)), size)),
        "a2a": ("clos_a2a", alltoall(topo, list(range(n)), size)),
    }
    for name, (tag, sched) in scheds.items():
        for pol in ALL_POLICIES:
            r = run_cached(tag, topo, sched, pol, cfg)
            rows.append(("fig9", f"{name}_pfc_frames", pol,
                         int(r.pause_count.sum())))
    return rows


def fig10_dlrm_e2e():
    """Fig 10: DLRM iteration = compute + exposed comm, per CC x {1D,2D}."""
    topo, n = paper_clos()
    cfg = engine_cfg(queue_stride=0)
    rows = []
    report = {}
    for algo in ("2d", "1d"):
        for pol in ALL_POLICIES:
            rep = simulate_dlrm_iteration(
                topo, list(range(n)), get_policy(pol),
                comm=DLRMCommSpec(allreduce_algo=algo), cfg=cfg,
                runner=RUNNER)
            rows.append(("fig10", f"dlrm_{algo}_iter_ms", pol,
                         round(rep.iteration_time * 1e3, 4)))
            rows.append(("fig10", f"dlrm_{algo}_exposed_ms", pol,
                         round(rep.exposed_comm * 1e3, 4)))
            rows.append(("fig10", f"dlrm_{algo}_pfc_frames", pol, rep.pfc_pauses))
            report[f"{algo}_{pol}"] = rep.__dict__
    save_json("fig10_dlrm.json", {k: {kk: (vv if not hasattr(vv, "item") else float(vv))
                                      for kk, vv in v.items()} for k, v in report.items()})
    rows.append(("fig10", "total_compute_ms", "-",
                 round(DLRMComputeProfile().total * 1e3, 4)))
    return rows


def fig11_static_window():
    """Beyond-paper: the paper's §IV-E proposed static-window CC vs PFC."""
    topo, n = paper_clos()
    cfg = engine_cfg(queue_stride=0)
    rows = []
    for algo in ("2d",):
        pfc = simulate_dlrm_iteration(topo, list(range(n)),
                                      get_policy("pfc"),
                                      comm=DLRMCommSpec(allreduce_algo=algo),
                                      cfg=cfg, runner=RUNNER)
        sw = simulate_dlrm_iteration(topo, list(range(n)),
                                     get_policy("static_window"),
                                     comm=DLRMCommSpec(allreduce_algo=algo),
                                     cfg=cfg, runner=RUNNER)
        rows.append(("fig11", "pfc_iter_ms", "pfc", round(pfc.iteration_time * 1e3, 4)))
        rows.append(("fig11", "sw_iter_ms", "static_window",
                     round(sw.iteration_time * 1e3, 4)))
        rows.append(("fig11", "pfc_frames", "pfc", pfc.pfc_pauses))
        rows.append(("fig11", "pfc_frames", "static_window", sw.pfc_pauses))
        rows.append(("fig11", "slowdown_pct", "static_window",
                     round((sw.iteration_time / pfc.iteration_time - 1) * 100, 2)))
    return rows
