"""One function per paper figure/table (paper Figs 3-10 + beyond-paper).

Each figure lists ``ScenarioSpec``s (fabric x workload x policy) and runs
them through the shared ``SweepRunner``; rows are CSV tuples
(figure,metric,...,value) and raw series land in experiments/bench/*.json.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (RUNNER, collective_size, downsample,
                               engine_cfg, paper_clos, paper_fabric,
                               run_cached, save_json, single_fabric)
from repro.core.cc import ALL_POLICIES, get_policy
from repro.core.engine import EngineConfig
from repro.core.scenario import CollectiveSpec, IncastSpec, ScenarioSpec
from repro.core.workload import (DLRMCommSpec, DLRMComputeProfile,
                                 simulate_dlrm_iteration,
                                 simulate_dlrm_policies)


def fig3_incast():
    """Fig 3: queue-length timeline + completion for 7->1 incast."""
    fab = single_fabric(8)
    wl = IncastSpec(n_senders=7, size_each=10e6)
    cfg = EngineConfig(dt=1e-6, max_steps=2000, max_extends=6)
    rows, series = [], {}
    for pol in ALL_POLICIES:
        r = run_cached("incast", ScenarioSpec(fab, wl, pol), cfg)
        q = r.dev_queue[:, 8]
        rows.append(("fig3", "completion_ms", pol, round(r.completion_time * 1e3, 4)))
        rows.append(("fig3", "max_queue_mb", pol, round(float(q.max()) / 1e6, 3)))
        rows.append(("fig3", "pfc_frames", pol, int(r.pause_count.sum())))
        series[pol] = downsample(q)
    save_json("fig3_queue_timelines.json", series)
    return rows


def fig4_single_switch_collectives():
    """Fig 4: single-switch All-Reduce / All-To-All show no congestion."""
    n = 8
    fab = single_fabric(n)
    size = 10e6
    cfg = EngineConfig(dt=1e-6, max_steps=3000, max_extends=6)
    rows, series = [], {}
    for name, kind in (("alltoall", "a2a"), ("allreduce", "1d")):
        wl = CollectiveSpec(kind, size)
        for pol in ("pfc", "dcqcn", "dctcp", "timely", "hpcc"):
            r = run_cached(f"ss_{name}", ScenarioSpec(fab, wl, pol), cfg)
            q = r.dev_queue[:, n]  # the switch
            rows.append(("fig4", f"{name}_completion_ms", pol,
                         round(r.completion_time * 1e3, 4)))
            rows.append(("fig4", f"{name}_max_queue_mb", pol,
                         round(float(q.max()) / 1e6, 3)))
            rows.append(("fig4", f"{name}_pfc_frames", pol, int(r.pause_count.sum())))
            series[f"{name}_{pol}"] = downsample(q)
    save_json("fig4_queue_timelines.json", series)
    return rows


def fig5_7_clos_queues():
    """Figs 5/6/7: ToR vs Spine queue timelines + ECMP imbalance (A2A)."""
    fab = paper_fabric()
    topo = fab.build()
    wl = CollectiveSpec("a2a", collective_size())
    cfg = engine_cfg()
    rows, series = [], {}
    tor = topo.meta["tor_devs"]
    spine = topo.meta["spine_devs"]
    for pol in ALL_POLICIES:
        r = run_cached("clos_a2a", ScenarioSpec(fab, wl, pol), cfg)
        tq = r.dev_queue[:, tor]
        sq = r.dev_queue[:, spine]
        rows.append(("fig6", "tor_max_queue_mb", pol, round(float(tq.max()) / 1e6, 3)))
        rows.append(("fig7", "spine_max_queue_mb", pol, round(float(sq.max()) / 1e6, 3)))
        if pol == "pfc":
            # Fig 5: per-spine imbalance under ECMP
            peaks = sq.max(axis=0)
            rows.append(("fig5", "spine_peak_imbalance", pol,
                         round(float(peaks.max() / max(peaks.min(), 1.0)), 2)))
            series["spines_pfc"] = [downsample(sq[:, i]) for i in range(min(3, sq.shape[1]))]
        series[f"tor_{pol}"] = downsample(tq.sum(axis=1))
        series[f"spine_{pol}"] = downsample(sq.sum(axis=1))
    save_json("fig5_7_queue_timelines.json", series)
    return rows


# one cache tag per workload kind, shared with figs 5-7/9 where equal
_AR_KINDS = {"ar_1d": "1d", "ar_2d": "2d", "ar_ring": "ring",
             "ar_hring": "hring", "a2a": "a2a"}


def _ar_tag(name):
    return "clos_a2a" if name == "a2a" else f"clos_{name}"


def fig8_completion():
    """Fig 8: completion time per collective algorithm per CC policy
    (paper: 1D/2D/A2A; beyond-paper: the registered ring variants too)."""
    fab = paper_fabric()
    size = collective_size()
    cfg = engine_cfg(queue_stride=0)   # no timeline consumed
    rows = []
    for name, kind in _AR_KINDS.items():
        wl = CollectiveSpec(kind, size)
        # ring variants are beyond-paper: bound their cost to the headline
        # policies (their flow count is P x the direct algorithms')
        pols = (("pfc", "dcqcn", "hpcc") if "ring" in kind else ALL_POLICIES)
        for pol in pols:
            r = run_cached(_ar_tag(name), ScenarioSpec(fab, wl, pol), cfg)
            # an exhausted step budget means completion is a lower bound,
            # not a measurement: mark the cell NaN + an explicit flag row
            ct = (float("nan") if r.extend_exhausted
                  else round(r.completion_time * 1e3, 4))
            rows.append(("fig8", f"{name}_completion_ms", pol, ct))
            if r.extend_exhausted:
                rows.append(("fig8", f"{name}_EXHAUSTED", pol, 1))
            elif not r.finished:
                rows.append(("fig8", f"{name}_UNFINISHED", pol, 1))
    return rows


def fig9_pfc_counts():
    """Fig 9: PAUSE-frame counts per workload per CC."""
    fab = paper_fabric()
    size = collective_size()
    cfg = engine_cfg(queue_stride=0)
    rows = []
    for name in ("ar_1d", "ar_2d", "a2a"):
        wl = CollectiveSpec(_AR_KINDS[name], size)
        for pol in ALL_POLICIES:
            r = run_cached(_ar_tag(name), ScenarioSpec(fab, wl, pol), cfg)
            rows.append(("fig9", f"{name}_pfc_frames", pol,
                         int(r.pause_count.sum())))
    return rows


def fig10_dlrm_e2e():
    """Fig 10: DLRM iteration = compute + exposed comm, per CC x {1D,2D}.

    The per-policy loop is one vmapped policy-axis dispatch per allreduce
    algorithm (``simulate_dlrm_policies``)."""
    topo, n = paper_clos()
    cfg = engine_cfg(queue_stride=0)
    rows = []
    report = {}
    for algo in ("2d", "1d"):
        reps = simulate_dlrm_policies(
            topo, list(range(n)), ALL_POLICIES,
            comm=DLRMCommSpec(allreduce_algo=algo), cfg=cfg, runner=RUNNER)
        for rep in reps:
            pol = rep.policy
            rows.append(("fig10", f"dlrm_{algo}_iter_ms", pol,
                         round(rep.iteration_time * 1e3, 4)))
            rows.append(("fig10", f"dlrm_{algo}_exposed_ms", pol,
                         round(rep.exposed_comm * 1e3, 4)))
            rows.append(("fig10", f"dlrm_{algo}_pfc_frames", pol, rep.pfc_pauses))
            report[f"{algo}_{pol}"] = rep.__dict__
    save_json("fig10_dlrm.json", {k: {kk: (vv if not hasattr(vv, "item") else float(vv))
                                      for kk, vv in v.items()} for k, v in report.items()})
    rows.append(("fig10", "total_compute_ms", "-",
                 round(DLRMComputeProfile().total * 1e3, 4)))
    return rows


def fig11_static_window():
    """Beyond-paper: the paper's §IV-E proposed static-window CC vs PFC."""
    topo, n = paper_clos()
    cfg = engine_cfg(queue_stride=0)
    rows = []
    for algo in ("2d",):
        pfc = simulate_dlrm_iteration(topo, list(range(n)),
                                      get_policy("pfc"),
                                      comm=DLRMCommSpec(allreduce_algo=algo),
                                      cfg=cfg, runner=RUNNER)
        sw = simulate_dlrm_iteration(topo, list(range(n)),
                                     get_policy("static_window"),
                                     comm=DLRMCommSpec(allreduce_algo=algo),
                                     cfg=cfg, runner=RUNNER)
        rows.append(("fig11", "pfc_iter_ms", "pfc", round(pfc.iteration_time * 1e3, 4)))
        rows.append(("fig11", "sw_iter_ms", "static_window",
                     round(sw.iteration_time * 1e3, 4)))
        rows.append(("fig11", "pfc_frames", "pfc", pfc.pfc_pauses))
        rows.append(("fig11", "pfc_frames", "static_window", sw.pfc_pauses))
        rows.append(("fig11", "slowdown_pct", "static_window",
                     round((sw.iteration_time / pfc.iteration_time - 1) * 100, 2)))
    return rows


def fig12_fabric_sweep():
    """Beyond-paper (Hoefler/Mittal direction): ECN x PFC-threshold grid
    per CC policy on a 4x-oversubscribed CLOS A2A — spine contention makes
    the fabric tuning decisive — one vmapped dispatch per policy."""
    import dataclasses
    fab = dataclasses.replace(paper_fabric(), oversubscription=4.0)
    topo = fab.build()
    sched = CollectiveSpec("a2a", collective_size() / 2).build_schedule(topo)
    cfg = engine_cfg(queue_stride=0)   # same integration step as figs 8/9
    # ECN ramp swept as *paired* (kmin, 4*kmin) points crossed with xoff —
    # not a kmin x kmax factorial, which would include inverted ramps
    pts = np.array([(k, 4.0 * k, x)
                    for k in (100e3, 400e3, 1000e3)
                    for x in (0.25e6, 1e6, 4e6)], np.float32)
    rows, series = [], {}
    for pol in ("dcqcn", "dctcp", "hpcc"):
        batch = RUNNER.run_batch(topo, sched, pol,
                                 stacked_fabric={"kmin": pts[:, 0],
                                                 "kmax": pts[:, 1],
                                                 "xoff": pts[:, 2]},
                                 cfg=cfg)
        b = batch.best()
        rows.append(("fig12", "best_completion_ms", pol,
                     round(float(batch.completion_time[b]) * 1e3, 4)))
        rows.append(("fig12", "best_kmin_kb", pol,
                     round(float(batch.fabric["kmin"][b]) / 1e3, 1)))
        rows.append(("fig12", "best_xoff_kb", pol,
                     round(float(batch.fabric["xoff"][b]) / 1e3, 1)))
        # spread/frame stats over *finished* members only: an unfinished
        # member's completion_time is a truncation artifact
        fin = batch.finished
        ct = batch.completion_time[fin]
        frames = batch.pause_count.sum(axis=1)[fin]
        rows.append(("fig12", "spread_pct", pol,
                     round(float((ct.max() / ct.min() - 1) * 100), 2)))
        rows.append(("fig12", "pfc_frames_min", pol, int(frames.min())))
        rows.append(("fig12", "pfc_frames_max", pol, int(frames.max())))
        rows.append(("fig12", "n_unfinished", pol, int((~fin).sum())))
        series[pol] = {
            "kmin": [float(v) for v in batch.fabric["kmin"]],
            "xoff": [float(v) for v in batch.fabric["xoff"]],
            "finished": [bool(v) for v in fin],
            "completion_ms": [float(v) * 1e3 for v in batch.completion_time],
            "pfc_frames": [float(v) for v in batch.pause_count.sum(axis=1)],
        }
    save_json("fig12_fabric_sweep.json", series)
    return rows


def fig13_fault_regimes():
    """Beyond-paper (Mittal/Hoefler direction): CC policies on a *faulty*
    fabric.  Two sweeps, each ONE vmapped dispatch over a stacked policy
    axis: (a) loss-rate x recovery-model (IRN vs go-back-N) on a lossy
    CLOS All-Reduce, (b) link-flap frequency.  A lane whose step budget
    ran out reports completion as NaN plus an ``_EXHAUSTED`` marker row —
    its comm time is a lower bound, not a measurement — and deadlocked /
    diverged lanes get their own marker rows (``BatchResults.lane_status``).
    """
    import warnings

    from repro.core.faults import FaultSpec

    fab = paper_fabric()
    wl = CollectiveSpec("1d", collective_size() / 2)
    cfg = engine_cfg(queue_stride=0)
    pols = ("dcqcn", "hpcc", "timely")
    spec = ScenarioSpec(fab, wl, pols,
                        fault_spec=FaultSpec(pfc_on=0.0))  # lossy-RoCE mode
    rows, series = [], {}

    def lane_rows(batch, tag_of):
        status = batch.lane_status()
        for i in range(batch.n):
            pol, tag = batch.policy_of(i), tag_of(i)
            if batch.extend_exhausted[i]:
                rows.append(("fig13", f"{tag}_completion_ms", pol,
                             float("nan")))
                rows.append(("fig13", f"{tag}_EXHAUSTED", pol, 1))
            else:
                rows.append(("fig13", f"{tag}_completion_ms", pol,
                             round(float(batch.completion_time[i]) * 1e3, 4)))
                if status[i] != "ok":
                    rows.append(("fig13", f"{tag}_{status[i].upper()}",
                                 pol, 1))
        return status

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        lossy = RUNNER.grid_spec(spec, fault_grid={
            "loss_rate": [0.0, 1e-5, 1e-3], "gbn": [0.0, 1.0]}, cfg=cfg)
        flappy = RUNNER.grid_spec(spec, fault_grid={
            "flap_period": [400e-6, 1600e-6], "flap_down": [100e-6]},
            cfg=cfg)

    def loss_tag(i):
        rec = "gbn" if lossy.fault["gbn"][i] > 0.5 else "irn"
        return f"loss{float(lossy.fault['loss_rate'][i]):g}_{rec}"

    loss_status = lane_rows(lossy, loss_tag)
    flap_status = lane_rows(
        flappy, lambda i: f"flap{float(flappy.fault['flap_period'][i]):g}s")
    for name, batch, status in (("loss_grid", lossy, loss_status),
                                ("flap_grid", flappy, flap_status)):
        series[name] = {
            "policy": [batch.policy_of(i) for i in range(batch.n)],
            "fault": {k: [float(x) for x in v]
                      for k, v in batch.fault.items()},
            "completion_ms": [float(v) * 1e3
                              for v in batch.completion_time],
            "lane_status": status,
        }
    save_json("fig13_fault_regimes.json", series)
    return rows
