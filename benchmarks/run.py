# One function per paper table/figure. Prints ``figure,metric,policy,value``
# CSV rows; roofline terms are derived from the dry-run artifacts when
# present (run ``python -m repro.launch.dryrun --all`` first for those).
from __future__ import annotations

import os
import time
import traceback


def main() -> None:
    from benchmarks import figures
    from benchmarks.common import emit
    from repro.common.cache import enable_compilation_cache

    enable_compilation_cache()   # repeat runs skip the XLA cold compiles
    t00 = time.time()
    print("figure,metric,policy,value")
    for fn in (figures.fig3_incast,
               figures.fig4_single_switch_collectives,
               figures.fig5_7_clos_queues,
               figures.fig8_completion,
               figures.fig9_pfc_counts,
               figures.fig10_dlrm_e2e,
               figures.fig11_static_window,
               figures.fig12_fabric_sweep,
               figures.fig13_fault_regimes):
        t0 = time.time()
        try:
            emit(fn())
        except Exception:
            print(f"{fn.__name__},ERROR,-,1")
            traceback.print_exc()
        emit([(fn.__name__, "wall_s", "-", round(time.time() - t0, 1))])

    # engine-step roofline: analytic, always available
    from benchmarks import roofline
    emit(roofline.engine_step_rows())

    # model roofline (reads dry-run artifacts if present)
    if os.path.isdir("experiments/dryrun") and os.listdir("experiments/dryrun"):
        print("--- roofline (from dry-run artifacts) ---")
        roofline.main()
    else:
        print("roofline,SKIPPED (run: python -m repro.launch.dryrun --all)")
    emit([("all", "total_wall_s", "-", round(time.time() - t00, 1))])


if __name__ == "__main__":
    main()
