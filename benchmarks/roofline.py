"""§Roofline: three-term analysis per (arch x shape x mesh) from the
dry-run artifacts in experiments/dryrun/*.json.

  compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
  memory term     = HLO_bytes / HBM_bw               (per chip)
  collective term = collective_bytes / link_bw       (per chip)

(the dry-run records trip-count-corrected per-device values, so the
"/chips" in the brief's formulas is already applied).  Hardware: TPU v5e —
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_PER_CHIP = 16e9  # v5e


def model_flops_per_device(rec: dict) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (fwd-only),
    per device."""
    from repro.configs import get_config
    cfg = get_config(rec["arch"])
    n_active = active_params(cfg, rec["n_params"])
    if rec["kind"] == "train":
        tokens = {"train_4k": 256 * 4096}.get(rec["shape"], 0)
        factor = 6.0
    elif rec["kind"] == "prefill":
        tokens = 32 * 32768
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = {"decode_32k": 128, "long_500k": 1}.get(rec["shape"], 1)
        factor = 2.0
    return factor * n_active * tokens / rec["n_devices"]


def active_params(cfg, n_total: int) -> float:
    if not getattr(cfg, "moe", False):
        return float(n_total)
    L_moe = cfg.n_layers - cfg.first_dense_layers
    routed = L_moe * cfg.n_experts * 3 * cfg.d_model * cfg.moe_d_ff
    return float(n_total - routed * (1 - cfg.top_k / cfg.n_experts))


def analyze(rec: dict) -> dict:
    comp = rec["flops"] / PEAK_FLOPS
    # memory term from the fused-floor bytes (TPU-like fusion); the raw
    # upper bound is recorded alongside (see DESIGN.md §8 caveats)
    memt = rec.get("bytes_floor", rec["bytes_accessed"]) / HBM_BW
    coll = rec["collective_bytes"].get("total", 0.0) / LINK_BW
    terms = {"compute_s": comp, "memory_s": memt, "collective_s": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    ideal = mf / PEAK_FLOPS
    frac = ideal / max(terms.values()) if max(terms.values()) > 0 else 0.0
    peak = rec["memory"].get("peak_bytes") or 0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": round(mf / rec["flops"], 4) if rec["flops"] else 0.0,
        "roofline_fraction": round(frac, 4),
        "memory_s_upper": round(rec["bytes_accessed"] / HBM_BW, 6),
        "peak_bytes_per_dev": peak,
        "fits_hbm": bool(peak and peak <= HBM_PER_CHIP),
    }


def main(dryrun_dir: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            continue
        try:
            rows.append(analyze(rec))
        except Exception as e:  # record parse issues, don't die
            rows.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                         "mesh": rec.get("mesh"), "error": str(e)})
    hdr = ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
           "dominant", "useful_ratio", "roofline_fraction", "fits_hbm"]
    out = [",".join(hdr)]
    for r in rows:
        out.append(",".join(str(r.get(k, "")) for k in hdr))
    csv = "\n".join(out)
    print(csv)
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.csv", "w") as f:
        f.write(csv + "\n")
    with open("experiments/roofline_full.json", "w") as f:
        json.dump(rows, f, indent=1, default=float)
    return rows


if __name__ == "__main__":
    main()
