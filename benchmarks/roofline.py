"""§Roofline: three-term analysis per (arch x shape x mesh) from the
dry-run artifacts in experiments/dryrun/*.json.

  compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
  memory term     = HLO_bytes / HBM_bw               (per chip)
  collective term = collective_bytes / link_bw       (per chip)

(the dry-run records trip-count-corrected per-device values, so the
"/chips" in the brief's formulas is already applied).  Hardware: TPU v5e —
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_PER_CHIP = 16e9  # v5e


def model_flops_per_device(rec: dict) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (fwd-only),
    per device."""
    from repro.configs import get_config
    cfg = get_config(rec["arch"])
    n_active = active_params(cfg, rec["n_params"])
    if rec["kind"] == "train":
        tokens = {"train_4k": 256 * 4096}.get(rec["shape"], 0)
        factor = 6.0
    elif rec["kind"] == "prefill":
        tokens = 32 * 32768
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = {"decode_32k": 128, "long_500k": 1}.get(rec["shape"], 1)
        factor = 2.0
    return factor * n_active * tokens / rec["n_devices"]


def active_params(cfg, n_total: int) -> float:
    if not getattr(cfg, "moe", False):
        return float(n_total)
    L_moe = cfg.n_layers - cfg.first_dense_layers
    routed = L_moe * cfg.n_experts * 3 * cfg.d_model * cfg.moe_d_ff
    return float(n_total - routed * (1 - cfg.top_k / cfg.n_experts))


def analyze(rec: dict) -> dict:
    comp = rec["flops"] / PEAK_FLOPS
    # memory term from the fused-floor bytes (TPU-like fusion); the raw
    # upper bound is recorded alongside (see DESIGN.md §8 caveats)
    memt = rec.get("bytes_floor", rec["bytes_accessed"]) / HBM_BW
    coll = rec["collective_bytes"].get("total", 0.0) / LINK_BW
    terms = {"compute_s": comp, "memory_s": memt, "collective_s": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    ideal = mf / PEAK_FLOPS
    frac = ideal / max(terms.values()) if max(terms.values()) > 0 else 0.0
    peak = rec["memory"].get("peak_bytes") or 0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": round(mf / rec["flops"], 4) if rec["flops"] else 0.0,
        "roofline_fraction": round(frac, 4),
        "memory_s_upper": round(rec["bytes_accessed"] / HBM_BW, 6),
        "peak_bytes_per_dev": peak,
        "fits_hbm": bool(peak and peak <= HBM_PER_CHIP),
    }


# -- engine-step roofline ---------------------------------------------------
# Analytic bytes/FLOP model of one fluid-engine step (repro.core.engine
# ``_make_step``), sized from the scenario's flow count.  Used two ways:
# ``benchmarks/run.py`` always emits these rows (no dry-run artifacts
# needed) and ``benchmarks/bench_engine.py`` records them next to the
# measured step timings in BENCH_engine.json.

MAXHOP = 4          # engine.MAXHOP: padded hop slots per flow
F32 = 4             # bytes per element, everything in the step is f32


def engine_step_roofline(n_flows: int, maxhop: int = MAXHOP,
                         n_state: int = 8, n_links: int = 64,
                         fanin: int = 64) -> dict:
    """Memory-traffic and FLOP estimate for one engine step at ``n_flows``.

    Two traffic models: ``fused`` counts each operand once per kernel
    (the ``step_impl="pallas"`` packing — repro.kernels.engine_step reads
    the 8 hop-shaped inputs + 3 flow inputs + state and writes state +
    rate/win/diagnostics in one pass); ``jnp`` adds the materialized
    intermediates the op-by-op path streams through memory (each hop-
    shaped temporary is written then re-read).  FLOPs are identical —
    the fusion win is pure traffic, so arithmetic intensity rises by
    the traffic ratio."""
    F, H, K = float(n_flows), float(maxhop), float(n_state)
    hop = F * H
    # stages 1-2: signals (mark/rtt/util over hops) + policy update
    sig_reads = 8 * hop + 3 * F + K * F
    sig_writes = K * F + 5 * F
    # mark, unmarked-product, rtt/util partials: ~6 hop-shaped temporaries
    # plus ~8 flow-shaped ones, each written and re-read by the next op
    sig_intermediate = 2 * (6 * hop + 8 * F)
    # stages 5-6: padded-gather segment reductions (per-hop demand x H,
    # qlink, qport): vals + int32 index matrix + output per reduction
    n_out = float(n_links)
    gat = (H + 2) * (hop + 2 * n_out * fanin + n_out)
    bytes_fused = F32 * (sig_reads + sig_writes + gat)
    bytes_jnp = bytes_fused + F32 * sig_intermediate
    # ~14 flops/lane for mark/rtt/util, ~45/flow for a DCQCN-class update,
    # one add per gathered element
    flops = 14 * hop + 45 * F + (H + 2) * n_out * fanin
    ridge = PEAK_FLOPS / HBM_BW
    out = {
        "n_flows": int(n_flows),
        "flops_per_step": flops,
        "bytes_fused": bytes_fused,
        "bytes_jnp": bytes_jnp,
        "traffic_ratio": round(bytes_jnp / bytes_fused, 3),
        "intensity_fused": round(flops / bytes_fused, 4),
        "intensity_jnp": round(flops / bytes_jnp, 4),
        "ridge_flop_per_byte": round(ridge, 1),
        "memory_bound": flops / bytes_fused < ridge,
        "est_step_us_fused_hbm": round(bytes_fused / HBM_BW * 1e6, 3),
        "est_step_us_jnp_hbm": round(bytes_jnp / HBM_BW * 1e6, 3),
    }
    return out


def engine_step_rows(sizes=(256, 7936, 65536)) -> list:
    """CSV rows (figure, metric, policy, value) for ``benchmarks/run.py``:
    the engine-step roofline at representative scenario sizes (8-GPU
    autotune regime, the 32-GPU headline All-Reduce, a paper-scale
    128-GPU All-to-All)."""
    rows = []
    for n in sizes:
        r = engine_step_roofline(n)
        tag = f"roofline_engine_step_{n}"
        for k in ("traffic_ratio", "intensity_fused", "intensity_jnp",
                  "est_step_us_fused_hbm", "memory_bound"):
            rows.append((tag, k, "-", r[k]))
    return rows


def main(dryrun_dir: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            continue
        try:
            rows.append(analyze(rec))
        except Exception as e:  # record parse issues, don't die
            rows.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                         "mesh": rec.get("mesh"), "error": str(e)})
    hdr = ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
           "dominant", "useful_ratio", "roofline_fraction", "fits_hbm"]
    out = [",".join(hdr)]
    for r in rows:
        out.append(",".join(str(r.get(k, "")) for k in hdr))
    csv = "\n".join(out)
    print(csv)
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.csv", "w") as f:
        f.write(csv + "\n")
    with open("experiments/roofline_full.json", "w") as f:
        json.dump(rows, f, indent=1, default=float)
    return rows


if __name__ == "__main__":
    main()
