"""Fluid-engine performance benchmark: warm steps/sec, sweep throughput,
and per-figure-scenario wall time.  Writes BENCH_engine.json.

Usage:
    PYTHONPATH=src python benchmarks/bench_engine.py [--smoke] [--out PATH]

``--smoke`` runs one warm repetition of the headline scenario plus the
fault, step_impl-comparison, backend-calibration and learned-CC
training-loop smokes (CI-friendly);
the full run adds the per-figure scenario timings, a vmap sweep-throughput
measurement and larger calibration probes.  The measured serial-vs-batched
crossover table (``sweep.calibrate_backend``) and the analytic engine-step
roofline land in BENCH_engine.json under "calibration" and
"roofline_engine_step".

The committed BENCH_engine.json demonstrates the PR-2 acceptance gate:
warm wall-clock of the headline scenario (32-GPU CLOS 1D All-Reduce,
dt=2e-6, max_steps=4000, max_extends=6, DCQCN) vs the seed engine.
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax

from repro.core.cc import get_policy
from repro.core.collectives import allreduce_1d, alltoall, incast
from repro.core.engine import EngineConfig, Simulator
from repro.core.sweep import SweepRunner
from repro.core.topology import clos, single_switch

# Seed-engine baseline for the headline scenario, measured on the dev
# container (2-core CPU, jax 0.4.x) immediately before the PR-2 rewrite:
# warm Simulator.run() of clos(2,2,8) allreduce_1d(32 GPUs, 32 MB) under
# DCQCN with EngineConfig(dt=2e-6, max_steps=4000, max_extends=6) took
# 46.8 s (cold 48.2 s), i.e. ~85 steps/s.  Override with --seed-warm-s
# when re-baselining on different hardware.
SEED_WARM_S = 46.8


def headline_case():
    topo = clos(n_racks=2, nodes_per_rack=2, gpus_per_node=8)
    sched = allreduce_1d(topo, list(range(32)), 32e6)
    cfg = EngineConfig(dt=2e-6, max_steps=4000, max_extends=6, queue_stride=0)
    return topo, sched, cfg


def bench_headline(reps: int) -> dict:
    topo, sched, cfg = headline_case()
    sim = Simulator(topo, sched, get_policy("dcqcn"), cfg)
    t0 = time.time()
    r = sim.run()
    cold = time.time() - t0
    warm = []
    for _ in range(reps):
        t0 = time.time()
        r = sim.run()
        warm.append(time.time() - t0)
    warm_s = min(warm)
    steps = r.meta["steps_run"]
    return {
        "scenario": "clos32_ar1d_dcqcn dt=2e-6 max_steps=4000 max_extends=6",
        "n_flows": sched.n_flows,
        "finished": r.finished,
        "completion_time_s": r.completion_time,
        "cold_s": round(cold, 3),
        "warm_s": round(warm_s, 3),
        "warm_reps": warm,
        "steps_run": steps,
        "steps_per_s": round(steps / warm_s, 1),
    }


def bench_sweep(B: int = 8) -> dict:
    """vmap throughput on the autotune-regime scenario (small fabric, short
    step budget): B DCQCN parameter sets in one compiled call vs the same
    B run serially.  On CPU the batched path wins where per-op dispatch
    dominates (small/medium scenarios — exactly the population-tuning and
    grid-sweep use cases); huge gather-bound scenarios prefer serial runs.
    """
    import numpy as np
    topo = clos(n_racks=1, nodes_per_rack=2, gpus_per_node=4)   # 8 GPUs
    sched = allreduce_1d(topo, list(range(8)), 8e6)
    cfg = EngineConfig(dt=1e-6, max_steps=2500, max_extends=0, queue_stride=0)
    runner = SweepRunner(cfg)
    policy = get_policy("dcqcn")
    scale = np.linspace(0.5, 2.0, B).astype(np.float32)
    stacked = {"rai_frac": 0.03 * scale, "timer": 55e-6 * scale}
    t0 = time.time()
    batch = runner.run_batch(topo, sched, policy, stacked)
    cold = time.time() - t0
    t0 = time.time()
    batch = runner.run_batch(topo, sched, policy, stacked)
    warm = time.time() - t0
    sim = runner.simulator(topo, sched, policy, cfg)
    t0 = time.time()
    for i in range(B):
        sim.run(cc_params=batch.param_set(i))
    serial = time.time() - t0
    # joint CC x fabric grid: after a same-shaped warmup the whole cross
    # product is one dispatch with zero new compiles
    from repro.core.sweep import compile_stats

    def fab_grid():
        return runner.grid(topo, sched, policy,
                           {"rai_frac": [0.01, 0.03]},
                           fabric_grid={"kmin": [200e3, 400e3],
                                        "xoff": [0.5e6, 1e6]})

    fab_grid()                      # warmup (compiles the B=8 batch shape)
    s0 = compile_stats()
    t0 = time.time()
    fgrid = fab_grid()
    fabric_grid_s = time.time() - t0
    return {
        "scenario": "clos8_ar1d dcqcn param sweep (autotune regime)",
        "batch": B,
        "cold_s": round(cold, 3),
        "warm_s": round(warm, 3),
        "warm_s_per_member": round(warm / B, 4),
        "serial_s_same_params": round(serial, 3),
        "vmap_speedup_vs_serial": round(serial / warm, 1),
        "all_finished": bool(batch.finished.all()),
        "fabric_grid_B8_s": round(fabric_grid_s, 3),
        "fabric_grid_recompiled": compile_stats() != s0,
        "fabric_grid_all_finished": bool(fgrid.finished.all()),
    }


def bench_policy_axis(policies=("pfc", "dcqcn", "dctcp", "timely", "hpcc")) -> dict:
    """The paper's policy-comparison loop on the 32-GPU CLOS All-Reduce:
    vmapped ``run_policy_axis`` (one stacked dispatch over B policies) vs
    serial ``run_policies`` (B compiled runs, each early-exiting).  The
    batched path integrates until the *slowest* member finishes, so the
    speedup is the dispatch/vectorization win net of that cost — on CPU
    it wins in the dispatch-bound regime (the ``small_scenario``
    sub-benchmark; cf. ``SweepRunner.batch_pays_off``) and loses on the
    gather-bound 7936-flow headline, where drivers auto-fall back to
    serial.  Accelerator backends vectorize the batch axis fully.
    """
    topo, sched, cfg = headline_case()
    runner = SweepRunner(cfg)
    B = len(policies)
    t0 = time.time()
    batch = runner.run_policy_axis(topo, sched, policies)
    cold = time.time() - t0
    t0 = time.time()
    batch = runner.run_policy_axis(topo, sched, policies)
    warm = time.time() - t0
    runner.run_policies(topo, sched, policies)          # warm the serial path
    t0 = time.time()
    serial = runner.run_policies(topo, sched, policies)
    serial_s = time.time() - t0
    import numpy as np
    agree = all(
        np.allclose(batch.completion_time[i], serial[i].completion_time,
                    rtol=1e-5)
        for i in range(B))
    # the dispatch-bound regime (8-GPU CLOS All-Reduce, the autotune/grid
    # scenario size): where the vmapped policy axis pays off on CPU
    from repro.core.topology import clos as _clos
    topo_s = _clos(n_racks=1, nodes_per_rack=2, gpus_per_node=4)
    sched_s = allreduce_1d(topo_s, list(range(8)), 8e6)
    cfg_s = EngineConfig(dt=1e-6, max_steps=2500, max_extends=0,
                         queue_stride=0)
    runner_s = SweepRunner(cfg_s)
    runner_s.run_policy_axis(topo_s, sched_s, policies)       # warmup
    t0 = time.time()
    small = runner_s.run_policy_axis(topo_s, sched_s, policies)
    small_warm = time.time() - t0
    runner_s.run_policies(topo_s, sched_s, policies)          # warmup
    t0 = time.time()
    runner_s.run_policies(topo_s, sched_s, policies)
    small_serial = time.time() - t0
    return {
        "scenario": "clos32_ar1d policy axis "
                    "(dt=2e-6 max_steps=4000 max_extends=6)",
        "policies": list(policies),
        "batch": B,
        "cold_s": round(cold, 3),
        "warm_s": round(warm, 3),
        "warm_s_per_policy": round(warm / B, 4),
        "serial_s": round(serial_s, 3),
        "vmap_speedup_vs_serial": round(serial_s / warm, 2),
        "all_finished": bool(batch.finished.all()),
        "matches_serial": agree,
        "policy_axis_pays_off_here": SweepRunner(cfg).policy_axis_pays_off(),
        "small_scenario": {
            "scenario": "clos8_ar1d policy axis (dispatch-bound regime)",
            "n_flows": sched_s.n_flows,
            "warm_s": round(small_warm, 3),
            "serial_s": round(small_serial, 3),
            "vmap_speedup_vs_serial": round(small_serial / small_warm, 2),
            "all_finished": bool(small.finished.all()),
        },
    }


def bench_faults() -> dict:
    """Fault-scenario smoke: one lossy-RoCE run (loss + IRN recovery, PFC
    off) and one link-flap run on the 8-GPU incast, plus the per-lane
    health fields — exercises the faulty compile path end to end in CI."""
    import warnings

    import numpy as np

    from repro.core.faults import FaultSpec

    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 5e6)
    cfg = EngineConfig(dt=1e-6, max_steps=1500, max_extends=3,
                       queue_stride=0)
    sim = Simulator(topo, sched, get_policy("dcqcn"), cfg)
    out = {}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        base = sim.run()
        t0 = time.time()
        lossy = sim.run(fault_spec=FaultSpec.lossy_roce(1e-4, "irn"))
        lossy_s = time.time() - t0
        t0 = time.time()
        flappy = sim.run(fault_spec=FaultSpec(flap_period=200e-6,
                                              flap_down=100e-6))
        flap_s = time.time() - t0
    out["lossless_completion_ms"] = round(base.completion_time * 1e3, 4)
    out["lossy"] = {
        "spec": "loss_rate=1e-4 irn pfc_off",
        "wall_s": round(lossy_s, 3),
        "completion_ms": round(lossy.completion_time * 1e3, 4),
        "lost_kb": round(float(np.sum(lossy.lost)) / 1e3, 2),
        "finished": lossy.finished,
        "pause_frames": int(lossy.pause_count.sum()),   # 0: PFC disabled
    }
    out["flap"] = {
        "spec": "flap_period=200us flap_down=100us",
        "wall_s": round(flap_s, 3),
        "completion_ms": round(flappy.completion_time * 1e3, 4),
        "finished": flappy.finished,
    }
    for tag in ("lossy", "flap"):
        assert out[tag]["finished"], f"fault smoke {tag!r} did not finish"
    assert out["lossy"]["completion_ms"] > out["lossless_completion_ms"]
    return out


def bench_figures() -> dict:
    """Warm wall time of small-scale versions of the figure scenarios."""
    out = {}
    cases = {
        "fig3_incast": (single_switch(8), None, "dcqcn",
                        EngineConfig(dt=1e-6, max_steps=2000, max_extends=6)),
        "fig5_7_clos_a2a": (clos(2, 2, 8), "a2a", "dcqcn",
                            EngineConfig(dt=2e-6, max_steps=4000,
                                         max_extends=6)),
        "fig8_clos_ar1d": (clos(2, 2, 8), "ar1d", "hpcc",
                           EngineConfig(dt=2e-6, max_steps=4000,
                                        max_extends=6, queue_stride=0)),
    }
    for tag, (topo, kind, pol, cfg) in cases.items():
        if kind == "a2a":
            sched = alltoall(topo, list(range(topo.n_gpus)), 32e6)
        elif kind == "ar1d":
            sched = allreduce_1d(topo, list(range(topo.n_gpus)), 32e6)
        else:
            sched = incast(topo, list(range(1, 8)), 0, 10e6)
        sim = Simulator(topo, sched, get_policy(pol), cfg)
        r = sim.run()
        t0 = time.time()
        r = sim.run()
        warm = time.time() - t0
        out[tag] = {"policy": pol, "warm_s": round(warm, 3),
                    "steps_run": r.meta["steps_run"],
                    "finished": r.finished}
    return out


def bench_step_impl() -> dict:
    """step_impl comparison smoke: the fused Pallas step path vs the jnp
    path on a small incast, correctness (allclose completion time) plus
    warm wall time.  Off-TPU the Pallas path runs in interpret mode
    (correctness configuration, not a speed claim — the wall-clock win
    needs a compiled accelerator backend; see README 'Backends and
    kernels')."""
    import dataclasses

    import numpy as np

    topo = single_switch(4)
    sched = incast(topo, [1, 2, 3], 0, 2e6)
    cfg_j = EngineConfig(dt=1e-6, max_steps=400, max_extends=1,
                         queue_stride=0, step_impl="jnp")
    cfg_p = dataclasses.replace(cfg_j, step_impl="pallas")
    out = {"backend": jax.default_backend(),
           "pallas_mode": ("compiled" if jax.default_backend() == "tpu"
                           else "interpret")}
    res = {}
    for tag, cfg in (("jnp", cfg_j), ("pallas", cfg_p)):
        sim = Simulator(topo, sched, get_policy("dcqcn"), cfg)
        r = sim.run()                       # warmup: compile
        t0 = time.time()
        r = sim.run()
        out[f"{tag}_warm_s"] = round(time.time() - t0, 3)
        res[tag] = r
    out["completion_allclose"] = bool(np.allclose(
        res["jnp"].completion_time, res["pallas"].completion_time,
        rtol=1e-4))
    assert out["completion_allclose"], "step_impl paths disagree"
    return out


def bench_calibration(smoke: bool = True) -> dict:
    """Measure the serial-vs-batched crossover table for the running
    backend (``sweep.calibrate_backend``) and return its JSON record —
    this is the table ``SweepRunner.batch_pays_off`` /
    ``policy_axis_pays_off`` / ``sharded_pays_off`` consult once cached.

    In ``--smoke`` mode a fresh persisted table (< 7 days, same jax
    version and device count; ``sweep.load_calibration``) short-circuits
    the measurement — the warm-start path fresh processes take."""
    from repro.core import sweep as sweep_mod
    if smoke:
        cached = sweep_mod.load_calibration(max_age_days=7.0)
        if cached is not None and cached.source == "measured":
            sweep_mod.set_calibration(cached)
            rec = cached.record()
            rec["from_disk_cache"] = True
            rec["cache_path"] = sweep_mod.calibration_cache_path()
            return rec
    cfg = EngineConfig(dt=2e-6, max_steps=300 if smoke else 800,
                       max_extends=1, queue_stride=0)
    t0 = time.time()
    cal = sweep_mod.calibrate_backend(
        probe_flows=(12, 90) if smoke else (90, 870, 1806),
        B=4 if smoke else 6, cfg=cfg)
    rec = cal.record()
    rec["from_disk_cache"] = False
    rec["measure_s"] = round(time.time() - t0, 3)
    rec["cache_path"] = sweep_mod.calibration_cache_path()
    return rec


def bench_sharded(B: int = 32) -> dict:
    """Sharded grid scale-out vs the single-device vmap: the same B-lane
    DCQCN parameter sweep through ``SweepRunner(mesh="auto")`` (shard_map
    over all local devices, round-robin lane placement) and through the
    un-sharded vmap, warm wall-clock both ways, plus the chunked-streaming
    per-device memory bound and a rtol-1e-5 equivalence check.

    Scaling efficiency = (vmap_s / sharded_s) / n_devices.  On real
    multi-device backends lanes parallelize; on a single-core host with
    *emulated* devices (XLA_FLAGS=--xla_force_host_platform_device_count)
    all shards share one core, so efficiency ~1/n_devices is expected —
    the emulated run validates placement/equivalence, not speed."""
    import numpy as np

    n_dev = len(jax.devices())
    out = {"backend": jax.default_backend(), "devices": n_dev}
    if n_dev < 2:
        out["skipped"] = ("single device; emulate with XLA_FLAGS="
                          "--xla_force_host_platform_device_count=8")
        return out
    topo = clos(n_racks=1, nodes_per_rack=2, gpus_per_node=4)    # 8 GPUs
    sched = allreduce_1d(topo, list(range(8)), 8e6)
    cfg = EngineConfig(dt=1e-6, max_steps=2500, max_extends=0,
                       queue_stride=0)
    vm = SweepRunner(cfg)
    sh = SweepRunner(cfg, mesh="auto")
    out["mesh_shape"] = {sh.mesh.axis_names[0]: sh.n_mesh_devices}
    policy = get_policy("dcqcn")
    scale = np.linspace(0.5, 2.0, B).astype(np.float32)
    stacked = {"rai_frac": 0.03 * scale}
    a = vm.run_batch(topo, sched, policy, stacked)       # warmup + compile
    t0 = time.time()
    a = vm.run_batch(topo, sched, policy, stacked)
    vmap_s = time.time() - t0
    b = sh.run_batch(topo, sched, policy, stacked)       # warmup + compile
    t0 = time.time()
    b = sh.run_batch(topo, sched, policy, stacked)
    shard_s = time.time() - t0
    speedup = vmap_s / shard_s
    out["batch"] = B
    out["vmap_warm_s"] = round(vmap_s, 3)
    out["sharded_warm_s"] = round(shard_s, 3)
    out["speedup_vs_vmap"] = round(speedup, 2)
    out["scaling_efficiency"] = round(speedup / n_dev, 3)
    out["matches_vmap"] = bool(np.allclose(
        a.completion_time, b.completion_time, rtol=1e-5))
    assert out["matches_vmap"], "sharded path diverged from vmap"
    # chunked streaming: per-device working set is bounded by the chunk,
    # not the grid — a 10k-lane atlas holds chunk/n_dev lane-states per
    # device at a time
    chunk = 2 * n_dev
    shc = SweepRunner(cfg, mesh="auto", chunk_lanes=chunk)
    c = shc.run_batch(topo, sched, policy, stacked)      # B/chunk chunks
    lane_bytes = sh.lane_state_bytes(topo, sched, policy)
    out["chunked_streaming"] = {
        "chunk_lanes": chunk,
        "n_chunks": -(-B // chunk),
        "lane_state_bytes": lane_bytes,
        "per_device_state_bytes": lane_bytes * chunk // n_dev,
        "matches_vmap": bool(np.allclose(
            a.completion_time, c.completion_time, rtol=1e-5)),
    }
    assert out["chunked_streaming"]["matches_vmap"]
    return out


def bench_learn(steps: int = 4) -> dict:
    """Learned-CC training-loop smoke: a few Adam steps of the
    gradient-through-sim trainer (``repro.learn.train.train_smoke``) on a
    small incast — asserts the loss actually decreases and records the
    measured optimizer-step throughput."""
    from repro.learn.train import train_smoke

    rec = train_smoke(steps=steps)
    assert rec["loss_decreased"], \
        f"training smoke did not descend: {rec}"
    assert rec["nonfinite_steps"] == 0, f"non-finite training step: {rec}"
    return rec


def bench_compilation_cache(smoke: bool = True) -> dict:
    """Cold-vs-warm persistent-compilation-cache timing.

    Compiles the sweep executable for a fresh shape (a true cold XLA
    compile, persisted to disk), then drops the in-memory executables
    (``jax.clear_caches()``) and compiles again — the second compile is
    served from the on-disk cache, which is exactly the fresh-process
    warm-start path CI and repeat bench runs take.  Run LAST: clearing
    the in-memory cache would distort any benchmark after it."""
    import numpy as np

    from repro.common.cache import (compilation_cache_entries,
                                    enable_compilation_cache)

    cache_dir = enable_compilation_cache()
    out = {"cache_dir": cache_dir, "enabled": cache_dir is not None}
    if cache_dir is None:
        return out
    # a shape no other bench uses, so the first compile is genuinely cold
    topo = single_switch(5)
    sched = allreduce_1d(topo, list(range(5)), 4e6)
    cfg = EngineConfig(dt=1e-6, max_steps=500 if smoke else 2000,
                       max_extends=0, queue_stride=0)
    runner = SweepRunner(cfg)
    policy = get_policy("dcqcn")
    stacked = {"rai_frac": np.asarray([0.02, 0.03, 0.05], np.float32)}
    entries0 = compilation_cache_entries(cache_dir)
    t0 = time.time()
    runner.run_batch(topo, sched, policy, stacked)
    cold_s = time.time() - t0
    t0 = time.time()
    runner.run_batch(topo, sched, policy, stacked)
    warm_s = time.time() - t0
    jax.clear_caches()
    runner._sims.clear()                 # prepared sims hold old buffers
    from repro.core import engine as engine_mod
    from repro.core import sweep as sweep_mod
    engine_mod._RUN_CACHE.clear()
    sweep_mod._BATCH_CACHE.clear()
    sweep_mod._SHARD_CACHE.clear()
    t0 = time.time()
    runner.run_batch(topo, sched, policy, stacked)
    disk_warm_cold_s = time.time() - t0
    out.update({
        "entries_before": entries0,
        "entries_after": compilation_cache_entries(cache_dir),
        "cold_compile_s": round(cold_s, 3),
        "warm_run_s": round(warm_s, 3),
        "disk_warm_compile_s": round(disk_warm_cold_s, 3),
        "compile_speedup": round(
            (cold_s - warm_s) / max(disk_warm_cold_s - warm_s, 1e-9), 1),
        "note": "disk_warm_compile_s = first run after clearing in-memory "
                "executables with the persistent cache populated — the "
                "fresh-process path; compare against cold_compile_s",
    })
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="headline scenario only, one warm rep")
    ap.add_argument("--out", default="BENCH_engine.json")
    ap.add_argument("--seed-warm-s", type=float, default=SEED_WARM_S)
    args = ap.parse_args()

    from repro.common.cache import enable_compilation_cache
    cache_dir = enable_compilation_cache()

    report = {
        "env": {"platform": platform.platform(),
                "jax": jax.__version__,
                "devices": [str(d) for d in jax.devices()],
                "compilation_cache_dir": cache_dir},
        "seed_baseline": {
            "warm_s": args.seed_warm_s,
            "note": "PR-1 seed engine, same scenario/config, measured on "
                    "the dev container before the PR-2 hot-path rewrite",
        },
    }
    report["headline"] = bench_headline(reps=1 if args.smoke else 3)
    report["speedup_vs_seed"] = round(
        args.seed_warm_s / report["headline"]["warm_s"], 1)
    report["faults"] = bench_faults()
    report["step_impl"] = bench_step_impl()
    report["calibration"] = bench_calibration(smoke=args.smoke)
    report["learn"] = bench_learn()
    report["sharded"] = bench_sharded()
    try:                         # run.py imports us as benchmarks.*;
        from benchmarks.roofline import engine_step_roofline
    except ImportError:          # direct script run: sys.path[0]=benchmarks/
        from roofline import engine_step_roofline
    report["roofline_engine_step"] = engine_step_roofline(
        report["headline"]["n_flows"])
    if not args.smoke:
        report["sweep_vmap"] = bench_sweep()
        report["policy_axis"] = bench_policy_axis()
        report["figure_scenarios"] = bench_figures()
    # last: clears the in-memory executable cache to measure the
    # disk-warm recompile path
    report["compilation_cache"] = bench_compilation_cache(smoke=args.smoke)

    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(json.dumps(report, indent=1))
    print(f"\nwrote {args.out}; speedup vs seed engine: "
          f"{report['speedup_vs_seed']}x")


if __name__ == "__main__":
    main()
