"""Shared benchmark machinery: cached spec simulations + CSV output.

Figures are driven by ``repro.core.scenario.ScenarioSpec``: each figure
lists specs (fabric x workload x policy) and hands them to the shared
``SweepRunner``; same-shaped specs reuse compiled engines across figures.

Scale knob: REPRO_BENCH_SCALE=paper|mid|small (small = 32 GPUs for CI,
mid = 64, paper = the paper's 128-GPU 8-rack CLOS)."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.engine import EngineConfig, Results
from repro.core.scenario import FabricSpec, ScenarioSpec
from repro.core.sweep import SweepRunner

# small = 32 GPUs/2 racks (CI), mid = 64 GPUs/4 racks (default: paper
# topology family at a tractable single-core runtime), paper = the full
# 128-GPU/8-rack platform of §III-B (hours of fluid sim on one CPU core)
SCALE = os.environ.get("REPRO_BENCH_SCALE", "mid")
OUTDIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")

_CACHE: dict = {}


def paper_fabric() -> FabricSpec:
    """The paper's CLOS family at the configured scale.

    oversubscription=2.0 -> 8 spines per 16 NIC downlinks, matching the
    seed ``clos()`` default (the Fig-5 ECMP-imbalance regime) so figure
    results stay comparable across PRs."""
    racks = {"small": 2, "mid": 4}.get(SCALE, 8)
    return FabricSpec(family="clos", n_racks=racks, nodes_per_rack=2,
                      gpus_per_node=8, oversubscription=2.0)


def single_fabric(n_gpus: int = 8) -> FabricSpec:
    return FabricSpec(family="single", n_racks=1, nodes_per_rack=1,
                      gpus_per_node=n_gpus)


def paper_clos():
    """(topology, n_gpus) — kept for drivers that need the raw topology."""
    spec = paper_fabric()
    return spec.build(), spec.n_gpus


def collective_size():
    return {"small": 32e6, "mid": 64e6}.get(SCALE, 128e6)


def engine_cfg(dt=2e-6, steps=4000, queue_stride=1):
    """``queue_stride=0`` for completion/PFC-count figures (no timeline)."""
    if SCALE == "small":
        return EngineConfig(dt=dt, max_steps=steps, max_extends=6,
                            queue_stride=queue_stride)
    return EngineConfig(dt=4e-6, max_steps=6000, max_extends=6,
                        queue_stride=queue_stride)


# one shared runner: same-shaped scenarios (all the per-policy loops, and
# schedules rebuilt per figure) reuse compiled engines instead of
# retracing.  mesh="auto" lays grid/policy-axis dispatches over all local
# devices when more than one is visible (sharded transparently; on a
# single device this is exactly the historical vmap path)
RUNNER = SweepRunner(mesh="auto")


def run_cached(tag: str, spec: ScenarioSpec, cfg: EngineConfig) -> Results:
    """Simulate a ScenarioSpec once per (tag, policy) and memoize."""
    pol = spec.policy if isinstance(spec.policy, str) else spec.policy.name
    key = (tag, pol)
    hit = _CACHE.get(key)
    # a queue-recording request upgrades a stride-0 entry cached by a
    # completion-only figure, so figure ordering can't break Figs 3-7
    if hit is None or (cfg.queue_stride > 0 and hit.dev_queue.size == 0):
        t0 = time.time()
        hit = RUNNER.run_spec(spec, cfg=cfg)
        hit.meta["wall_s"] = time.time() - t0
        _CACHE[key] = hit
    return hit


def emit(rows: list[tuple]):
    for r in rows:
        print(",".join(str(x) for x in r), flush=True)


def save_json(name: str, obj):
    """Atomic (tmp-file + rename) so a benchmark killed mid-write leaves
    the previous sidecar intact instead of truncated JSON — the same
    discipline as the campaign journal (``repro.core.campaign``)."""
    os.makedirs(OUTDIR, exist_ok=True)
    path = os.path.join(OUTDIR, name)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    os.replace(tmp, path)


def downsample(x: np.ndarray, n: int = 200) -> list:
    if len(x) <= n:
        return [float(v) for v in x]
    idx = np.linspace(0, len(x) - 1, n).astype(int)
    return [float(v) for v in x[idx]]
