"""Shared benchmark machinery: cached simulations + CSV output.

Scale knob: REPRO_BENCH_SCALE=paper|small (default paper = the paper's
128-GPU 8-rack CLOS; small = 32 GPUs for quick runs)."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.cc import ALL_POLICIES, get_policy
from repro.core.engine import EngineConfig, Results
from repro.core.sweep import SweepRunner
from repro.core.topology import clos, single_switch

# small = 32 GPUs/2 racks (CI), mid = 64 GPUs/4 racks (default: paper
# topology family at a tractable single-core runtime), paper = the full
# 128-GPU/8-rack platform of §III-B (hours of fluid sim on one CPU core)
SCALE = os.environ.get("REPRO_BENCH_SCALE", "mid")
OUTDIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")

_CACHE: dict = {}


def paper_clos():
    if SCALE == "small":
        return clos(n_racks=2, nodes_per_rack=2, gpus_per_node=8), 32
    if SCALE == "mid":
        return clos(n_racks=4, nodes_per_rack=2, gpus_per_node=8), 64
    return clos(n_racks=8, nodes_per_rack=2, gpus_per_node=8), 128


def collective_size():
    return {"small": 32e6, "mid": 64e6}.get(SCALE, 128e6)


def engine_cfg(dt=2e-6, steps=4000, queue_stride=1):
    """``queue_stride=0`` for completion/PFC-count figures (no timeline)."""
    if SCALE == "small":
        return EngineConfig(dt=dt, max_steps=steps, max_extends=6,
                            queue_stride=queue_stride)
    return EngineConfig(dt=4e-6, max_steps=6000, max_extends=6,
                        queue_stride=queue_stride)


# one shared runner: same-shaped scenarios (all the per-policy loops, and
# schedules rebuilt per figure) reuse compiled engines instead of retracing
RUNNER = SweepRunner()


def run_cached(tag: str, topo, sched, policy_name: str,
               cfg: EngineConfig) -> Results:
    key = (tag, policy_name)
    hit = _CACHE.get(key)
    # a queue-recording request upgrades a stride-0 entry cached by a
    # completion-only figure, so figure ordering can't break Figs 3-7
    if hit is None or (cfg.queue_stride > 0 and hit.dev_queue.size == 0):
        t0 = time.time()
        hit = RUNNER.run(topo, sched, get_policy(policy_name), cfg=cfg)
        hit.meta["wall_s"] = time.time() - t0
        _CACHE[key] = hit
    return hit


def emit(rows: list[tuple]):
    for r in rows:
        print(",".join(str(x) for x in r), flush=True)


def save_json(name: str, obj):
    os.makedirs(OUTDIR, exist_ok=True)
    with open(os.path.join(OUTDIR, name), "w") as f:
        json.dump(obj, f, indent=1, default=float)


def downsample(x: np.ndarray, n: int = 200) -> list:
    if len(x) <= n:
        return [float(v) for v in x]
    idx = np.linspace(0, len(x) - 1, n).astype(int)
    return [float(v) for v in x[idx]]
