"""Quickstart: the two halves of this repo in 60 seconds.

1. Train a (reduced) TinyLlama for 30 steps on CPU with the full stack
   (AdamW + remat/scan + deterministic data).
2. Simulate the paper's incast microbenchmark under three RoCE CC policies
   and print the Fig-3-style summary.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import smoke_model
from repro.configs.base import TrainConfig
from repro.data.pipeline import lm_batch
from repro.train.train_step import init_train_state, make_train_step


def train_demo():
    m = smoke_model("tinyllama-1.1b")
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=5, total_steps=30)
    params, opt = init_train_state(m, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(make_train_step(m, tcfg))
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in
                 lm_batch(0, i, 8, 64, m.cfg.vocab).items()}
        params, opt, metrics = step(params, opt, batch)
        if i % 10 == 0 or i == 29:
            print(f"  step {i:3d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e}")
    return params


def netsim_demo():
    # declarative scenario layer: one spec per simulation point, one shared
    # runner (same-shaped specs reuse compiled engines)
    from repro.core import (EngineConfig, FabricSpec, IncastSpec,
                            ScenarioSpec, SweepRunner)
    fab = FabricSpec(family="single", n_racks=1, nodes_per_rack=1,
                     gpus_per_node=8)
    wl = IncastSpec(n_senders=7, size_each=10e6)
    runner = SweepRunner(EngineConfig(dt=1e-6, max_steps=2000, max_extends=5))
    print("  policy          completion   max switch queue   PAUSE frames")
    for name in ("pfc", "dcqcn", "timely"):
        r = runner.run_spec(ScenarioSpec(fab, wl, name))
        q = r.dev_queue[:, 8].max() / 1e6
        print(f"  {name:14s} {r.completion_time*1e3:8.3f} ms {q:12.2f} MB"
              f" {int(r.pause_count.sum()):10d}")
    # the same comparison as ONE vmapped dispatch: a spec whose policy is a
    # tuple declares a policy axis (cc.stack_policies under the hood)
    topo, sched, _ = ScenarioSpec(fab, wl, ("pfc", "dcqcn", "timely")).build()
    batch = runner.run_policy_axis(topo, sched, ("pfc", "dcqcn", "timely"))
    print("  policy axis (one vmapped call):",
          ", ".join(f"{batch.policy_of(i)}={batch.completion_time[i]*1e3:.3f}ms"
                    for i in range(batch.n)))


if __name__ == "__main__":
    print("== 1. training (reduced tinyllama, CPU) ==")
    train_demo()
    print("== 2. RoCE CC incast microbenchmark (paper Fig 3) ==")
    netsim_demo()
