"""Beyond-paper: gradient-based CC parameter tuning through the
differentiable fluid simulator.

The paper complains that "DCQCN has many parameters that need to be tuned"
and that per-workload tuning "is not a feasible solution".  Because our
network layer is pure JAX, d(completion)/d(params) exists: this demo tunes
DCQCN's increase rate + EWMA gain on the incast microbenchmark by plain
gradient descent — no grid search.

Run:  PYTHONPATH=src python examples/cc_autotune.py
"""
from repro.core.autotune import autotune
from repro.core.cc import make_dcqcn
from repro.core.collectives import incast
from repro.core.engine import EngineConfig, simulate
from repro.core.topology import single_switch


def main():
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 10e6)
    cfg = EngineConfig(dt=2e-6, max_steps=2200, max_extends=0)

    # population-based: 4 jittered members descend in ONE vmapped
    # simulation per step; member 0 is the paper-default parameterisation
    res = autotune(topo, sched, make_dcqcn(), ["rai_frac", "rhai_frac", "g"],
                   steps=10, lr=0.25, cfg=cfg, population=4)
    print("history (soft cost = integral of undelivered fraction):")
    for h in res.history:
        print("  step %2d cost %.6f rai=%.4f rhai=%.4f g=%.5f"
              % (h["step"], h["cost"], h["rai_frac"], h["rhai_frac"], h["g"]))
    print(f"baseline {res.baseline_cost:.6f} -> tuned {res.tuned_cost:.6f}")

    run_cfg = EngineConfig(dt=1e-6, max_steps=2000, max_extends=5)
    before = simulate(topo, sched, make_dcqcn(), run_cfg)
    tuned_pol = make_dcqcn(rai_frac=res.params["rai_frac"],
                           rhai_frac=res.params["rhai_frac"], g=res.params["g"])
    after = simulate(topo, sched, tuned_pol, run_cfg)

    def mean_fct(r):
        import numpy as np
        return float(np.mean(r.t_finish[np.isfinite(r.t_finish)]))

    # soft cost ~ MEAN flow completion (integral of undelivered traffic);
    # report both mean and max so the objective/metric link is explicit
    print(f"mean flow completion: default {mean_fct(before)*1e3:.3f} ms"
          f" -> tuned {mean_fct(after)*1e3:.3f} ms")
    print(f"last-flow completion: default {before.completion_time*1e3:.3f} ms"
          f" -> tuned {after.completion_time*1e3:.3f} ms"
          f" (PFC-only optimum = 2.80 ms)")


if __name__ == "__main__":
    main()
