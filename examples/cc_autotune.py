"""Beyond-paper: gradient-based CC *and fabric* tuning through the
differentiable fluid simulator.

The paper complains that "DCQCN has many parameters that need to be tuned"
and that per-workload tuning "is not a feasible solution".  Because our
network layer is pure JAX, d(completion)/d(params) exists: this demo tunes
DCQCN's increase rate + EWMA gain on the incast microbenchmark by plain
gradient descent — no grid search — and then tunes the *fabric's* ECN
marking threshold the same way (FabricParams is a traced input, so the
fabric gradient costs no extra compiles).

Run:  PYTHONPATH=src python examples/cc_autotune.py
"""
from repro.core.autotune import autotune_spec
from repro.core.cc import make_dcqcn
from repro.core.engine import EngineConfig
from repro.core.scenario import FabricSpec, IncastSpec, ScenarioSpec
from repro.core.sweep import SweepRunner

FABRIC = FabricSpec(family="single", n_racks=1, nodes_per_rack=1,
                    gpus_per_node=8)
WORKLOAD = IncastSpec(n_senders=7, size_each=10e6)


def main():
    spec = ScenarioSpec(fabric=FABRIC, workload=WORKLOAD,
                        policy=make_dcqcn())
    cfg = EngineConfig(dt=2e-6, max_steps=2200, max_extends=0)

    # population-based: 4 jittered members descend in ONE vmapped
    # simulation per step; member 0 is the paper-default parameterisation
    res = autotune_spec(spec, ["rai_frac", "rhai_frac", "g"],
                        steps=10, lr=0.25, cfg=cfg, population=4)
    print("history (soft cost = integral of undelivered fraction;")
    print(" descent scale + bounds come from each param's declared ParamSpec):")
    for h in res.history:
        proj = f"  [clamped: {','.join(h['projected'])}]" if h["projected"] else ""
        print("  step %2d cost %.6f rai=%.4f rhai=%.4f g=%.5f%s"
              % (h["step"], h["cost"], h["rai_frac"], h["rhai_frac"],
                 h["g"], proj))
    print(f"baseline {res.baseline_cost:.6f} -> tuned {res.tuned_cost:.6f}")

    run_cfg = EngineConfig(dt=1e-6, max_steps=2000, max_extends=5)
    runner = SweepRunner(run_cfg)
    before = runner.run_spec(spec)
    tuned_pol = make_dcqcn(rai_frac=res.params["rai_frac"],
                           rhai_frac=res.params["rhai_frac"], g=res.params["g"])
    after = runner.run_spec(ScenarioSpec(FABRIC, WORKLOAD, tuned_pol))

    def mean_fct(r):
        import numpy as np
        return float(np.mean(r.t_finish[np.isfinite(r.t_finish)]))

    # soft cost ~ MEAN flow completion (integral of undelivered traffic);
    # report both mean and max so the objective/metric link is explicit
    print(f"mean flow completion: default {mean_fct(before)*1e3:.3f} ms"
          f" -> tuned {mean_fct(after)*1e3:.3f} ms")
    print(f"last-flow completion: default {before.completion_time*1e3:.3f} ms"
          f" -> tuned {after.completion_time*1e3:.3f} ms"
          f" (PFC-only optimum = 2.80 ms)")

    # fabric-side tuning: hold DCQCN at its defaults and descend the ECN
    # marking ramp instead (the knob the paper's operators would turn)
    fres = autotune_spec(spec, [], fabric_keys=["kmin", "kmax"],
                         steps=6, lr=0.3, cfg=cfg, population=3)
    print(f"fabric-only tuning: baseline {fres.baseline_cost:.6f} -> "
          f"tuned {fres.tuned_cost:.6f} "
          f"(kmin {float(fres.fabric.kmin)/1e3:.0f} kB, "
          f"kmax {float(fres.fabric.kmax)/1e3:.0f} kB)")


if __name__ == "__main__":
    main()
