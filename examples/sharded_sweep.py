"""Sharded sweep execution: the same grid through the single-device vmap
and through a device mesh, equivalence-checked, plus streamed chunking.

On a machine with one device this demo emulates 8 before importing jax
(the `XLA_FLAGS=--xla_force_host_platform_device_count=8` testing recipe
from README "Scaling sweeps across devices").  Emulated devices share the
host's cores — the point here is placement and equivalence, not speed;
see BENCH_engine.json "sharded" for honest scaling numbers.

Run:  PYTHONPATH=src python examples/sharded_sweep.py
"""
import os
import time

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import jax                                            # noqa: E402
import numpy as np                                    # noqa: E402

from repro.core import EngineConfig, SweepRunner      # noqa: E402
from repro.core.collectives import allreduce_1d       # noqa: E402
from repro.core.topology import single_switch         # noqa: E402


def main():
    print(f"devices: {len(jax.devices())} ({jax.default_backend()})")
    topo = single_switch(8)
    sched = allreduce_1d(topo, list(range(8)), 8e6)
    cfg = EngineConfig(dt=1e-6, max_steps=2500, max_extends=0,
                       queue_stride=0)

    vm = SweepRunner(cfg)                 # mesh=None: historical vmap path
    sh = SweepRunner(cfg, mesh="auto")    # grid axis over all devices
    print(f"mesh: {sh.mesh}  lanes/device state: "
          f"{sh.lane_state_bytes(topo, sched, 'dcqcn')} B/lane")

    # a 22-lane CC x fabric grid (non-divisible: 8-device mesh pads to 24
    # by edge-repeating, then masks the padding back out)
    grid = {"rai_frac": list(np.geomspace(0.005, 0.1, 11))}
    fabric_grid = {"kmin": [200e3, 400e3]}
    for name, runner in (("vmap", vm), ("sharded", sh)):
        runner.grid(topo, sched, "dcqcn", grid, fabric_grid)   # warm up
        t0 = time.time()
        batch = runner.grid(topo, sched, "dcqcn", grid, fabric_grid)
        print(f"  {name:8s} B={batch.n:3d} warm {time.time()-t0:6.3f}s "
              f"best lane #{batch.best()} "
              f"ct={batch.completion_time[batch.best()]*1e3:.3f}ms")
    a = vm.grid(topo, sched, "dcqcn", grid, fabric_grid)
    b = sh.grid(topo, sched, "dcqcn", grid, fabric_grid)
    print("  equivalent (rtol 1e-5):",
          np.allclose(a.completion_time, b.completion_time, rtol=1e-5))

    # streamed chunking: the same grid in chunks of one mesh-width — the
    # per-device working set is chunk/n_dev lanes regardless of grid size
    shc = SweepRunner(cfg, mesh="auto", chunk_lanes=sh.n_mesh_devices)
    c = shc.grid(topo, sched, "dcqcn", grid, fabric_grid)
    print(f"  chunked  B={c.n:3d} chunks of {shc._chunk_size(c.n)}: "
          "equivalent",
          np.allclose(a.completion_time, c.completion_time, rtol=1e-5))


if __name__ == "__main__":
    main()
