"""Beyond-paper: train a *learned* CC policy through the simulator.

The paper closes by calling for "an optimized, yet low-overhead,
congestion control scheme based on the characteristics of distributed
training platforms".  ``repro.learn`` builds one: a tiny per-flow MLP
(registered as the 8th policy ``"mlp"``) whose weights are flat
``ParamSpec`` entries, trained end-to-end by Adam on the engine's
differentiable soft cost with a rematerialized backward pass
(``Simulator.soft_cost_fn(remat=True)``).

This demo runs a short version of the full pipeline:

1. a few Adam steps on a two-scenario curriculum (a healthy incast and a
   lossy go-back-N incast — the loss regime gives the objective an
   interior optimum instead of a fill-the-pipe plateau);
2. a head-to-head against the classical policies on a held-out scenario
   via one vmapped ``run_policy_axis`` dispatch.

The committed trained weights (``src/repro/learn/mlp_weights.json``, from
``scripts/train_mlp_cc.py``) are what ``cc.get_policy("mlp")`` loads; the
short loop here re-derives a rougher version of them from scratch.

Run:  PYTHONPATH=src python examples/learn_cc.py
"""
from repro.core.engine import EngineConfig
from repro.core.faults import FaultSpec
from repro.core.scenario import FabricSpec, IncastSpec, ScenarioSpec
from repro.learn.train import TrainConfig, heldout_eval, make_task, train

FABRIC = FabricSpec(family="single", n_racks=1, nodes_per_rack=1,
                    gpus_per_node=8)
WORKLOAD = IncastSpec(n_senders=7, size_each=2e6)


def main():
    cfg = TrainConfig(steps=12, lr=0.08)
    engine_cfg = EngineConfig(dt=2e-6, max_steps=1500, max_extends=0,
                              queue_stride=0)
    curriculum = [
        ScenarioSpec(FABRIC, WORKLOAD, "mlp", name="incast8"),
        ScenarioSpec(FABRIC, WORKLOAD, "mlp", name="incast8_gbn",
                     fault_spec=FaultSpec.lossy_roce(1e-3, "gbn")),
    ]
    tasks = [make_task(s, engine_cfg=engine_cfg, corners=(None,),
                       train_cfg=cfg) for s in curriculum]

    print("training 'mlp' through the simulator "
          "(loss = per-scenario-normalized soft cost):")
    res = train(cfg, tasks=tasks)
    for h in res.history:
        print("  step %2d loss %.4f |g| %.3g%s"
              % (h["step"], h["loss"], h["grad_norm"],
                 "  [non-finite, frozen]" if h["nonfinite"] else ""))
    print(f"loss {res.baseline_loss:.4f} -> {res.final_loss:.4f}")

    # held-out: a 16-way incast (a fan-in the curriculum never saw),
    # every registered policy in one batched dispatch
    print("\nheld-out 16-way incast, all 8 policies in one dispatch:")
    ev = heldout_eval(
        specs=[ScenarioSpec(FabricSpec(family="single", n_racks=1,
                                       nodes_per_rack=1, gpus_per_node=16),
                            IncastSpec(15, 2e6), "mlp",
                            name="heldout_incast16")],
        cc_overrides=res.weights)
    row = ev["scenarios"][0]
    for pol, ms in sorted(row["completion_ms"].items(), key=lambda kv: kv[1]):
        mark = "  <- learned" if pol == "mlp" else ""
        print(f"  {pol:14s} {ms:8.3f} ms  [{row['lane_status'][pol]}]{mark}")
    print(f"mlp vs best classical ({row['best_classical']}): "
          f"{row['vs_best_pct']:+.1f}%   "
          f"vs worst ({row['worst_classical']}): {row['vs_worst_pct']:+.1f}%")


if __name__ == "__main__":
    main()
