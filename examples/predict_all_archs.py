"""Beyond-paper: CC-policy sensitivity for EVERY architecture.

The paper answers "does the RoCE CC policy matter?" for DLRM only.  This
driver reads each architecture's *measured* per-device collective traffic
(trip-count-corrected, from the compiled train_4k dry-run artifacts in
experiments/dryrun/) and replays an equivalent one-iteration communication
load on the paper's CLOS fabric under each CC policy — each point is one
``ScenarioSpec`` (fabric x CollectiveSpec workload x policy) on a shared
``SweepRunner``.

Calibration: per-device wire bytes per kind B_k are matched by sizing a
hierarchical All-Reduce (B_ar) and a direct All-To-All (B_a2a) so each
GPU's NIC moves the measured number of bytes (DESIGN.md §7.3).

Run after the dry-run sweep:
  PYTHONPATH=src python examples/predict_all_archs.py
"""
import glob
import json

from repro.core.engine import EngineConfig
from repro.core.scenario import CollectiveSpec, FabricSpec, ScenarioSpec
from repro.core.sweep import SweepRunner

POLICIES = ("pfc", "dcqcn", "dctcp", "timely", "hpcc", "static_window")
FABRIC = FabricSpec(family="clos", n_racks=4, nodes_per_rack=2,
                    gpus_per_node=8, oversubscription=2.0)  # 64 GPUs, 8 spines


def arch_comm_profile(rec):
    coll = rec["collective_bytes"]
    dev = 1  # bytes are already per-device
    ar = (coll.get("all-reduce", 0) + coll.get("all-gather", 0)
          + coll.get("reduce-scatter", 0)) / dev
    a2a = coll.get("all-to-all", 0) / dev
    return ar, a2a


def equiv_workloads(fab: FabricSpec, ar_bytes_per_gpu, a2a_bytes_per_gpu):
    """Size collectives so each GPU's NIC moves the measured bytes."""
    n, gpn = fab.n_gpus, fab.gpus_per_node
    n_nodes = n // gpn
    out = []
    # hierarchical AR: NIC bytes/GPU = 2*S*(n_nodes-1)/(gpn*n_nodes)
    if ar_bytes_per_gpu > 0:
        S_ar = ar_bytes_per_gpu * gpn * n_nodes / (2 * max(n_nodes - 1, 1))
        out.append(CollectiveSpec("2d", S_ar, n_chunks=2))
    if a2a_bytes_per_gpu > 0:
        # direct a2a: NIC bytes/GPU ~ S*(n - gpn)/n
        S_a2a = a2a_bytes_per_gpu * n / max(n - gpn, 1)
        out.append(CollectiveSpec("a2a", S_a2a, n_chunks=2))
    return out


def main():
    cfg = EngineConfig(dt=4e-6, max_steps=4000, max_extends=6, queue_stride=0)
    # one runner across all archs: equal-shaped schedules (same topo, same
    # chunking) hit the same compiled engine instead of retracing per arch
    runner = SweepRunner(cfg)
    files = sorted(glob.glob("experiments/dryrun/*_train_4k_sp.json"))
    if not files:
        print("no dry-run artifacts; run: python -m repro.launch.dryrun --all")
        return
    print(f"{'arch':20s} {'AR GB/dev':>10s} {'A2A GB/dev':>10s}  " +
          " ".join(f"{p:>9s}" for p in POLICIES) + "   (comm time, ms)")
    for path in files:
        rec = json.load(open(path))
        if rec.get("skipped"):
            continue
        ar, a2a = arch_comm_profile(rec)
        # scale one training step's traffic to an ~100 MB/GPU slice so each
        # fluid sim stays ~4 ms of fabric time (a full step is seconds);
        # relative CC sensitivity is scale-free for long flows
        scale = min(1.0, 100e6 / max(ar + a2a, 1.0))
        workloads = equiv_workloads(FABRIC, ar * scale, a2a * scale)
        times = []
        for pol in POLICIES:
            t = 0.0
            for wl in workloads:
                r = runner.run_spec(ScenarioSpec(FABRIC, wl, pol))
                t += r.completion_time if r.finished else float("nan")
            times.append(t)
        base = times[0]
        print(f"{rec['arch']:20s} {ar/1e9:10.1f} {a2a/1e9:10.1f}  " +
              " ".join(f"{t*1e3:7.2f}ms" for t in times) +
              f"   spread {((max(times)-min(times))/base*100):.1f}%")


if __name__ == "__main__":
    main()
