"""End-to-end driver (deliverable b): train the paper's DLRM for a few
hundred steps with fault tolerance ON, then simulate the same model's
iteration communication under every RoCE CC policy (paper Fig 10).

Run:  PYTHONPATH=src python examples/dlrm_end_to_end.py [--steps 200]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import smoke_model
from repro.configs.base import TrainConfig
from repro.core.cc import ALL_POLICIES, get_policy
from repro.core.engine import EngineConfig
from repro.core.topology import clos
from repro.core.workload import DLRMCommSpec, simulate_dlrm_iteration
from repro.data.pipeline import dlrm_batch
from repro.ft.fault_tolerance import FailureInjector, RunnerConfig, TrainRunner
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args()

    # --- 1. real DLRM training with an injected failure + restart ---------
    m = smoke_model("dlrm")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=10, total_steps=args.steps)
    params, opt = init_train_state(m, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(make_train_step(m, tcfg))

    def make_batch(s):
        return {k: jnp.asarray(v) for k, v in dlrm_batch(0, s, args.batch, m.cfg).items()}

    with tempfile.TemporaryDirectory() as ckpt:
        runner = TrainRunner(RunnerConfig(ckpt, checkpoint_every=50),
                             step, make_batch,
                             injector=FailureInjector((args.steps // 2,)))
        params, opt = runner.run(params, opt, args.steps)
    losses = [x["loss"] for x in runner.metrics_log]
    print(f"DLRM train: steps={len(losses)} restarts={runner.restarts} "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f} (chance=0.693)")

    # --- 2. the paper's question: does the CC policy matter? --------------
    topo = clos(n_racks=2, nodes_per_rack=2, gpus_per_node=8)
    gpus = list(range(32))
    cfg = EngineConfig(dt=2e-6, max_steps=2500, max_extends=6)
    print(f"{'policy':14s} {'iter ms':>8s} {'exposed ms':>11s} {'PAUSE':>8s}")
    base = None
    for pol in ALL_POLICIES:
        rep = simulate_dlrm_iteration(topo, gpus, get_policy(pol),
                                      comm=DLRMCommSpec(allreduce_algo="2d"),
                                      cfg=cfg)
        base = base or rep.iteration_time
        print(f"{pol:14s} {rep.iteration_time*1e3:8.3f} {rep.exposed_comm*1e3:11.3f}"
              f" {rep.pfc_pauses:8d}  ({(rep.iteration_time/base-1)*100:+.1f}%)")


if __name__ == "__main__":
    main()
