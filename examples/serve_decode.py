"""Serving example: batched prefill+decode on a (reduced) gemma2 with
sliding-window ring caches, plus the Pallas flash-decode kernel check.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_model
from repro.serve.engine import Request, ServeEngine


def main():
    m = smoke_model("gemma2-9b")
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, m.cfg.vocab, 16, dtype=np.int32),
                    max_new_tokens=8) for i in range(8)]
    eng = ServeEngine(m, params, batch_slots=4, max_len=48)
    for r in eng.run(reqs):
        print(f"req {r.rid}: generated {r.tokens.tolist()}")

    # the TPU decode kernel vs its oracle on this model's geometry
    from repro.kernels.flash_decode.ops import gqa_decode_attention
    from repro.kernels.flash_decode.ref import flash_decode_ref
    B, S, Hkv, Dh = 2, 256, m.cfg.n_kv_heads, m.cfg.head_dim
    G = m.cfg.n_heads // Hkv
    q = jax.random.normal(jax.random.PRNGKey(1), (B, 1, m.cfg.n_heads, Dh))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, Dh))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, Hkv, Dh))
    out = gqa_decode_attention(q, k, v, jnp.asarray([S, S - 30]), block_s=128)
    ref = flash_decode_ref(q.reshape(B, Hkv, G, Dh), k, v,
                           jnp.asarray([S, S - 30])).reshape(out.shape)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"flash_decode kernel max|err| vs oracle: {err:.2e}")


if __name__ == "__main__":
    main()
