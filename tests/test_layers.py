"""Attention-core equivalences: every fast path vs the dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.models import layers as L


def _qkv(key, B, S, Hq, Hkv, D, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, Hq, D), dtype)
    k = jax.random.normal(kk, (B, S, Hkv, D), dtype)
    v = jax.random.normal(kv, (B, S, Hkv, D), dtype)
    return q, k, v


@pytest.mark.parametrize("S,block", [(256, 64), (512, 128)])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_blockwise_matches_dense(S, block, softcap):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, S, 4, 2, 32)
    ref = L.dense_attention(q, k, v, causal=True, softcap=softcap)
    out = L.blockwise_attention(q, k, v, causal=True, softcap=softcap,
                                block_q=block, block_k=block, split_wedge=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("S", [2048, 4096])
def test_wedge_matches_dense(S):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, S, 2, 1, 16)
    ref = L.dense_attention(q, k, v, causal=True)
    out = L.blockwise_attention(q, k, v, causal=True, block_q=256, block_k=256,
                                split_wedge=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


def test_prefix_lm_mask():
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 64, 2, 1, 16)
    out = L.dense_attention(q, k, v, causal=True, prefix_len=16)
    # token 0 must attend tokens 0..15 (bidirectional prefix): differs from causal
    causal = L.dense_attention(q, k, v, causal=True)
    assert not np.allclose(np.asarray(out[:, 0]), np.asarray(causal[:, 0]))
    # last token: same receptive field either way
    np.testing.assert_allclose(np.asarray(out[:, -1]), np.asarray(causal[:, -1]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S,W", [(256, 64), (300, 128)])
def test_local_matches_dense_window(S, W):
    q, k, v = _qkv(jax.random.PRNGKey(3), 2, S, 4, 2, 32)
    ref = L.dense_attention(q, k, v, causal=True, window=W)
    out = L.local_attention(q, k, v, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_decode_matches_dense_last_token():
    B, S, Hq, Hkv, D = 2, 128, 4, 2, 32
    q, k, v = _qkv(jax.random.PRNGKey(4), B, S, Hq, Hkv, D)
    ref = L.dense_attention(q, k, v, causal=True)[:, -1:]
    out = L.decode_attention(q[:, -1:], k, v, length=jnp.asarray(S))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_decode_respects_length_mask():
    B, S = 1, 64
    q, k, v = _qkv(jax.random.PRNGKey(5), B, S, 2, 2, 16)
    out_40 = L.decode_attention(q[:, -1:], k, v, length=jnp.asarray(40))
    # the decode query attends exactly keys [0, length)
    ref = L.dense_attention(q[:, -1:], k[:, :40], v[:, :40], causal=False)
    np.testing.assert_allclose(np.asarray(out_40), np.asarray(ref), rtol=2e-5, atol=2e-5)


@given(st.integers(2, 6).map(lambda x: 2 ** x))
@settings(max_examples=8, deadline=None)
def test_rope_preserves_norm(dim):
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, dim))
    pos = jnp.arange(8)[None]
    y = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    D = 32
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, D))

    def score(m, n):
        qm = L.apply_rope(q, jnp.asarray([[m]]), 10000.0)
        kn = L.apply_rope(k, jnp.asarray([[n]]), 10000.0)
        return float(jnp.sum(qm * kn))

    assert abs(score(5, 3) - score(10, 8)) < 1e-4
    assert abs(score(7, 7) - score(0, 0)) < 1e-4


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = L._softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0 + 1e-5
