"""HLO comm extraction + trip-count-aware counter (roofline instrument)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.hlo_comm import CollectiveOp, collective_link_bytes, extract, summarize
from repro.core.hlo_counter import totals


def _compile_text(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_counter_scan_matmul_exact():
    def f(x, w, w2):
        def body(c, _):
            return c @ w, None
        y, _ = lax.scan(body, x, None, length=5)
        return y @ w2

    txt = _compile_text(
        f,
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 512), jnp.float32))
    t = totals(txt)
    expect = 5 * 2 * 128 * 256 * 256 + 2 * 128 * 512 * 256
    assert t.flops == expect


def test_counter_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = lax.scan(outer, x, None, length=4)
        return y

    txt = _compile_text(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                        jax.ShapeDtypeStruct((64, 64), jnp.float32))
    t = totals(txt)
    assert t.flops == 12 * 2 * 64 * 64 * 64


def test_counter_batched_dot():
    def f(x, w):
        return jnp.einsum("bij,bjk->bik", x, w)
    txt = _compile_text(f, jax.ShapeDtypeStruct((8, 32, 64), jnp.float32),
                        jax.ShapeDtypeStruct((8, 64, 16), jnp.float32))
    t = totals(txt)
    assert t.flops == 2 * 8 * 32 * 16 * 64


def test_extract_parses_collectives():
    hlo = """
  %ar = f32[1024,512]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag.1 = bf16[64,128]{1,0} all-gather(%y), replica_groups=[16,16], dimensions={0}
  %a2a = f32[32]{0} all-to-all(%z), replica_groups={{0,1},{2,3}}
"""
    ops = extract(hlo)
    kinds = {o.kind for o in ops}
    assert kinds == {"all-reduce", "all-gather", "all-to-all"}
    ar = [o for o in ops if o.kind == "all-reduce"][0]
    assert ar.bytes_total == 1024 * 512 * 4
    assert ar.group_size == 4
    ag = [o for o in ops if o.kind == "all-gather"][0]
    assert ag.group_size == 16 and ag.n_groups == 16


def test_summarize_and_link_bytes():
    ops = [CollectiveOp("all-reduce", 1000, 4, 1),
           CollectiveOp("all-gather", 1000, 4, 1)]
    s = summarize(ops)
    assert s["total"] == 2000
    lb = collective_link_bytes(ops)
    np.testing.assert_allclose(lb, 1000 * 2 * 3 / 4 + 1000 * 3 / 4)
