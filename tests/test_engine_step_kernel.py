"""Fused engine-step kernel correctness (repro.kernels.engine_step).

Runs the Pallas kernels in interpret mode (the CI configuration on CPU;
``repro.kernels.default_interpret``) against the pure-jnp oracle in
``engine_step.ref`` and against the engine's jnp path end to end:

* ``fused_step`` (stages 1-2: signals + policy update) must be allclose
  (rtol 1e-5) to the reference for EVERY kernel-eligible registered
  policy, lossless and lossy;
* the padded-gather segment reduction (+ fused PFC hysteresis) must match
  ``engine._reduce``'s "gather" strategy exactly;
* a full engine run with ``step_impl="pallas"`` must be allclose to
  ``step_impl="jnp"``;
* the default path (``step_impl="auto"`` -> "jnp" off-accelerator) must
  stay bitwise on the PR-2 goldens (it shares the executable with an
  explicit ``step_impl="jnp"`` by construction — asserted here);
* ``SweepRunner`` batching decisions must follow the measured crossover
  table once ``calibrate_backend`` has cached one.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cc, sweep
from repro.core.engine import EngineConfig, _cfg_static, resolve_step_impl, simulate
from repro.kernels.engine_step import ops as es_ops
from repro.kernels.engine_step import ref as es_ref

pytestmark = pytest.mark.kernel

MAXHOP = 4
F = 200          # deliberately not a multiple of 128: exercises padding


def _rand_case(rng, n_flows=F, lossy=False):
    """Random-but-plausible stage-1 inputs for one flow population."""
    H = MAXHOP
    hopmask = (rng.random((n_flows, H)) < 0.7).astype(np.float32)
    hopmask[:, 0] = 1.0
    caps = rng.uniform(10e9, 50e9, (n_flows, H)).astype(np.float32)
    kw = dict(
        q_d=(rng.uniform(0, 3e6, (n_flows, H)) * hopmask).astype(np.float32),
        tx_d=(rng.uniform(0, 50e9, (n_flows, H)) * hopmask).astype(
            np.float32),
        caps=caps,
        ecn_mask=(rng.random((n_flows, H)) < 0.8).astype(np.float32)
        * hopmask,
        hopmask=hopmask,
        kmin_h=np.full((n_flows, H), 400e3, np.float32),
        kmax_h=np.full((n_flows, H), 1600e3, np.float32),
        pmax_h=np.full((n_flows, H), 0.2, np.float32),
        base_rtt=rng.uniform(2e-6, 20e-6, n_flows).astype(np.float32),
        line=np.full(n_flows, 25e9, np.float32),
        loss=(rng.uniform(0, 2e3, n_flows).astype(np.float32) if lossy
              else np.zeros(n_flows, np.float32)),
        t=np.float32(3.3e-4),
        dt=1e-6,
        t_base_util=1e-5,
    )
    return {k: jnp.asarray(v) if isinstance(v, np.ndarray) else v
            for k, v in kw.items()}


def _rand_state(policy, rng, n_flows=F):
    keys = cc.kernel_state_keys(policy)
    line = jnp.full((n_flows,), 25e9, jnp.float32)
    ctx = cc.FlowCtx(line=line, bdp=line * 5e-6,
                     fanin=jnp.full((n_flows,), 4.0, jnp.float32),
                     n_flows=n_flows)
    st = policy.init(ctx)
    # perturb so the update sees non-initial state
    return {k: v * jnp.asarray(rng.uniform(0.5, 1.5, n_flows), jnp.float32)
            for k, v in st.items()}, keys


ALL = list(cc.REGISTRY)


@pytest.mark.parametrize("lossy", [False, True], ids=["lossless", "lossy"])
@pytest.mark.parametrize("pol", ALL)
def test_fused_step_matches_ref(pol, lossy):
    """Kernel (interpret) vs pure-jnp oracle for every registered policy."""
    policy = cc.get_policy(pol)
    assert cc.kernel_eligible(policy)
    rng = np.random.default_rng(hash(pol) % 2**31 + lossy)
    case = _rand_case(rng, lossy=lossy)
    state, _ = _rand_state(policy, rng)
    st_k, rate_k, win_k = es_ops.fused_step(
        policy, state=state, params=None, interpret=True, **case)
    st_r, rate_r, win_r = es_ref.fused_step_ref(
        policy, state=state, params=None,
        **{k: v for k, v in case.items()})
    np.testing.assert_allclose(rate_k, rate_r, rtol=1e-5)
    np.testing.assert_allclose(win_k, win_r, rtol=1e-5)
    for k in st_r:
        np.testing.assert_allclose(st_k[k], np.broadcast_to(st_r[k], (F,)),
                                   rtol=1e-5, err_msg=f"state[{k!r}]")


def test_fused_step_param_overrides_ride_smem():
    """Non-default CC params must reach the kernel (packed SMEM row)."""
    policy = cc.get_policy("dcqcn")
    rng = np.random.default_rng(7)
    case = _rand_case(rng)
    state, _ = _rand_state(policy, rng)
    # ecn_thresh=2.0 disables rate cuts entirely — guaranteed to differ
    # from the defaults on marked flows
    over = {"ecn_thresh": 2.0, "g": 0.3}
    st_k, rate_k, _ = es_ops.fused_step(policy, state=state, params=over,
                                        interpret=True, **case)
    st_r, rate_r, _ = es_ref.fused_step_ref(policy, state=state,
                                            params=over, **case)
    np.testing.assert_allclose(rate_k, rate_r, rtol=1e-5)
    # and the override actually changed the result vs defaults
    _, rate_d, _ = es_ops.fused_step(policy, state=state, params=None,
                                     interpret=True, **case)
    assert not np.allclose(rate_k, rate_d, rtol=1e-5)


def test_batched_tiles_match_per_lane():
    """B sweep lanes folded into the kernel grid == B separate calls."""
    from repro.kernels.engine_step.engine_step import (
        fused_signals_policy_tiled)
    policy = cc.get_policy("dcqcn")
    rng = np.random.default_rng(11)
    B = 3
    cases = [_rand_case(np.random.default_rng(100 + b)) for b in range(B)]
    states = [_rand_state(policy, np.random.default_rng(200 + b))[0]
              for b in range(B)]
    n_pad = (-F) % 128
    from repro.kernels.engine_step.ops import _tile_flat, _tile_hop
    hop_keys = ("q_d", "tx_d", "caps", "ecn_mask", "hopmask", "kmin_h",
                "kmax_h", "pmax_h")
    hop = tuple(jnp.concatenate([_tile_hop(c[k], n_pad, fill=1.0)
                                 for c in cases]) for k in hop_keys)
    flat = tuple(jnp.concatenate([_tile_flat(c[k], n_pad, fill=1.0)
                                  for c in cases])
                 for k in ("base_rtt", "line", "loss"))
    st4d = jnp.concatenate([
        jnp.pad(cc.pack_state(policy, s, n_flows=F), ((0, 0), (0, n_pad)),
                constant_values=1.0).reshape(1, -1, (F + n_pad) // 128, 128)
        for s in states])
    p2d = jnp.tile(cc.pack_params(policy, None)[None], (B, 1))
    outs = fused_signals_policy_tiled(
        policy, hop, flat, st4d, p2d, cases[0]["t"], dt=1e-6,
        t_base_util=1e-5, interpret=True)
    keys = cc.kernel_state_keys(policy)
    for b in range(B):
        st_r, rate_r, win_r = es_ref.fused_step_ref(
            policy, state=states[b], params=None, **cases[b])
        np.testing.assert_allclose(outs[1][b].reshape(-1)[:F],
                                   np.broadcast_to(rate_r, (F,)), rtol=1e-5)
        np.testing.assert_allclose(outs[2][b].reshape(-1)[:F],
                                   np.broadcast_to(win_r, (F,)), rtol=1e-5)
        for j, k in enumerate(keys):
            np.testing.assert_allclose(
                outs[0][b, j].reshape(-1)[:F],
                np.broadcast_to(st_r[k], (F,)), rtol=1e-5,
                err_msg=f"lane {b} state[{k!r}]")


def test_segment_reduce_matches_gather():
    """Padded-gather kernel == engine._reduce's gather strategy (exact)."""
    rng = np.random.default_rng(3)
    n_in, n_out, C = 777, 21, 37
    vals = jnp.asarray(rng.uniform(0, 1e6, n_in), jnp.float32)
    idx = rng.integers(0, n_in + 50, n_out * C)       # some OOB -> 0 fill
    idx = jnp.asarray(np.minimum(idx, n_in), jnp.int32)
    got = es_ops.segment_reduce(vals, idx, n_out, C, interpret=True)
    want = es_ref.segment_reduce_ref(vals, idx, n_out, C)
    # kernel sums the full padded 128-lane row (zeros in the tail), so
    # association order can differ from the (n_out, C) reshape by an ULP
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)


def test_segment_reduce_pfc_matches_ref():
    rng = np.random.default_rng(5)
    n_in, n_out, C = 512, 17, 31
    vals = jnp.asarray(rng.uniform(0, 2e6, n_in), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n_in, n_out * C), jnp.int32)
    xoff = jnp.asarray(rng.uniform(5e6, 20e6, n_out), jnp.float32)
    xon = xoff * 0.8
    can = jnp.asarray(rng.random(n_out) < 0.5)
    prev = jnp.asarray(rng.random(n_out) < 0.5)
    q_k, p_k = es_ops.segment_reduce_pfc(vals, idx, n_out, C, xoff, xon,
                                         can, prev, interpret=True)
    q_r, p_r = es_ref.segment_reduce_pfc_ref(vals, idx, n_out, C, xoff,
                                             xon, can, prev)
    np.testing.assert_allclose(np.asarray(q_k), np.asarray(q_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


# -- engine dispatch ---------------------------------------------------------

def _scenario():
    from repro.core.collectives import incast
    from repro.core.topology import single_switch
    topo = single_switch(8)
    return topo, incast(topo, list(range(1, 8)), 0, 5e6)


@pytest.mark.parametrize("pol", ["dcqcn", "hpcc", "pfc"])
def test_engine_pallas_matches_jnp(pol):
    """Full run: fused-kernel step vs the jnp step, same physics."""
    topo, sched = _scenario()
    cfg = EngineConfig(dt=1e-6, max_steps=1200, max_extends=2,
                       queue_stride=0)
    outs = {}
    for impl in ("jnp", "pallas"):
        outs[impl] = simulate(topo, sched, cc.get_policy(pol),
                              dataclasses.replace(cfg, step_impl=impl))
    a, b = outs["jnp"], outs["pallas"]
    assert a.finished == b.finished
    np.testing.assert_allclose(a.completion_time, b.completion_time,
                               rtol=1e-4)
    np.testing.assert_allclose(a.t_finish, b.t_finish, rtol=1e-4)
    np.testing.assert_allclose(a.delivered, b.delivered, rtol=1e-4)
    np.testing.assert_allclose(a.pause_count, b.pause_count,
                               rtol=1e-3, atol=1.0)


def test_default_impl_is_jnp_off_accelerator_and_bitwise_golden():
    """``step_impl="auto"`` resolves to the jnp step off-accelerator and
    shares its compiled executable (identical static config), so the
    default path reproduces the PR-2 goldens bitwise; one golden scenario
    is re-checked here under an explicit ``step_impl="jnp"``."""
    cfg = EngineConfig()
    expect = "jnp" if jax.default_backend() not in ("tpu", "gpu") \
        else "pallas"
    assert resolve_step_impl(cfg) == expect
    assert _cfg_static(cfg) == _cfg_static(
        dataclasses.replace(cfg, step_impl=resolve_step_impl(cfg)))

    from _engine_scenarios import scenarios
    gold = json.load(open(os.path.join(os.path.dirname(__file__), "golden",
                                       "engine_seed.json")))
    tag, topo, sched, pols, cfg = next(iter(scenarios()))
    g = gold[f"{tag}/{pols[0]}"]
    r = simulate(topo, sched, cc.get_policy(pols[0]),
                 dataclasses.replace(cfg, step_impl="jnp"))
    np.testing.assert_allclose(r.completion_time, g["completion_time"],
                               rtol=1e-5)


def test_resolve_step_impl_rejects_unknown():
    with pytest.raises(ValueError):
        resolve_step_impl(EngineConfig(step_impl="vulkan"))


# -- calibration-driven batching decisions -----------------------------------

def test_pays_off_follows_measured_crossover():
    """batch/policy-axis decisions come from the cached measured table."""
    def fake(kind, n, B, cfg):
        # batched wins below 1000 flows for sweeps, never for the axis
        if kind == "sweep":
            return n, 1.0, (0.5 if n < 1000 else 2.0)
        return n, 1.0, 2.0

    sweep.reset_calibration()
    try:
        cal = sweep.calibrate_backend(probe_flows=(100, 1600), B=4,
                                      _measure=fake)
        assert cal.source == "measured"
        assert 100 < cal.crossover["sweep"] < 1600
        assert cal.crossover["policy_axis"] == 0.0
        runner = sweep.SweepRunner()
        small = type("S", (), {"n_flows": 64})()
        big = type("S", (), {"n_flows": 4096})()
        assert runner.batch_pays_off(small)
        assert not runner.batch_pays_off(big)
        assert not runner.policy_axis_pays_off()
        assert not runner.policy_axis_pays_off(small)

        # all probes winning -> batching always on, n_flows-independent
        cal = sweep.calibrate_backend(probe_flows=(100, 1600), B=4,
                                      _measure=lambda k, n, B, c:
                                      (n, 2.0, 1.0))
        assert cal.crossover["sweep"] == float("inf")
        assert runner.batch_pays_off(big)
        assert runner.policy_axis_pays_off()

        # records are JSON-serializable (inf encoded)
        rec = cal.record()
        json.dumps(rec)
        assert rec["crossover"]["sweep"] == "inf"
    finally:
        sweep.reset_calibration()


def test_calibration_defaults_match_bench_measurements():
    """Uncalibrated CPU falls back to the BENCH_engine-derived defaults."""
    sweep.reset_calibration()
    cal = sweep.get_calibration("cpu")
    assert cal.source == "default"
    assert cal.crossover == {"sweep": 2048.0, "policy_axis": 0.0}
    assert sweep.get_calibration("tpu").pays_off("sweep", 10**9)
