"""Fluid-engine invariants: conservation, bounds, PFC hysteresis, deps."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.cc import get_policy
from repro.core.collectives import ScheduleBuilder, incast
from repro.core.engine import EngineConfig, simulate
from repro.core.topology import single_switch, clos

CFG = EngineConfig(dt=1e-6, max_steps=1500, max_extends=5)


def test_single_flow_line_rate():
    topo = single_switch(4)
    b = ScheduleBuilder(topo)
    g = b.new_group("x")
    b.add_flow(1, 0, 10e6, g)
    r = simulate(topo, b.build(), get_policy("pfc"), CFG)
    assert r.finished
    ideal = 10e6 / 25e9
    assert ideal * 0.999 <= r.completion_time <= ideal * 1.05  # f32 time


def test_byte_conservation():
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 5e6)
    r = simulate(topo, sched, get_policy("pfc"), CFG)
    assert r.finished
    np.testing.assert_allclose(r.delivered.sum(), sched.size.sum(), rtol=1e-3)


@pytest.mark.parametrize("pol", ["pfc", "dcqcn", "dctcp", "hpcc", "static_window"])
def test_completion_at_least_bottleneck_bound(pol):
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 2e6)
    r = simulate(topo, sched, get_policy(pol), CFG)
    assert r.finished
    assert r.completion_time >= 7 * 2e6 / 25e9 * 0.995


def test_pfc_bounds_switch_queue():
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 10e6)
    cfg = EngineConfig(dt=1e-6, max_steps=1500, max_extends=5, xoff=1e6, xon=0.8e6)
    r = simulate(topo, sched, get_policy("pfc"), cfg)
    sw_q = r.dev_queue[:, 8]
    # per-port xoff=1MB, 7 ports -> switch holds <~ 7*xoff + one dt of slack
    assert sw_q.max() <= 7 * 1e6 + 7 * 25e9 * cfg.dt * 1.5
    assert r.pause_count.sum() > 0


def test_dependency_groups_serialize():
    topo = single_switch(4)
    b = ScheduleBuilder(topo)
    g1 = b.new_group("first")
    b.add_flow(1, 0, 5e6, g1)
    g2 = b.new_group("second")
    b.add_flow(2, 0, 5e6, g2, dep=g1)
    r = simulate(topo, b.build(), get_policy("pfc"), CFG)
    assert r.finished
    t1, t2 = r.group_time
    assert t2 > t1
    assert t2 >= 2 * (5e6 / 25e9) * 0.99


def test_compute_marker_delay():
    topo = single_switch(4)
    b = ScheduleBuilder(topo)
    g1 = b.new_group("compute")
    b.add_marker(g1, delay=500e-6)
    g2 = b.new_group("comm")
    b.add_flow(1, 0, 1e6, g2, dep=g1)
    r = simulate(topo, b.build(), get_policy("pfc"), CFG)
    assert r.finished
    assert r.group_time[0] >= 500e-6 - 2e-6
    assert r.group_time[1] >= 500e-6 + 1e6 / 25e9 * 0.99


@given(st.integers(2, 6), st.floats(0.5e6, 8e6))
@settings(max_examples=10, deadline=None)
def test_property_conservation_and_bound(n_senders, size):
    topo = single_switch(8)
    sched = incast(topo, list(range(1, n_senders + 1)), 0, size)
    r = simulate(topo, sched, get_policy("dctcp"), CFG)
    if not r.finished:  # pathological tiny sizes may need more steps
        return
    np.testing.assert_allclose(r.delivered.sum(), sched.size.sum(), rtol=2e-3)
    assert r.completion_time >= n_senders * size / 25e9 * 0.98


def test_nvlink_path_faster_than_nic():
    topo = clos(n_racks=1, nodes_per_rack=2, gpus_per_node=4)
    b = ScheduleBuilder(topo)
    g1 = b.new_group("intra")   # same node: NVLink at 200 GB/s
    b.add_flow(0, 1, 50e6, g1)
    r1 = simulate(topo, b.build(), get_policy("pfc"), CFG)
    b2 = ScheduleBuilder(topo)
    g2 = b2.new_group("inter")  # across nodes: NIC at 25 GB/s
    b2.add_flow(0, 4, 50e6, g2)
    r2 = simulate(topo, b2.build(), get_policy("pfc"), CFG)
    assert r1.completion_time < r2.completion_time / 4
