"""Logical->mesh sharding rules incl. divisibility fallback + a real
8-device lower/compile round (subprocess with forced device count)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.common.sharding import MeshRules

def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(sizes, names)
    except TypeError:   # jax<=0.4.x signature: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, sizes)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_basic_rules():
    r = MeshRules.create(MESH)
    assert r.pspec(("vocab", "embed"), (32000, 2048)) == P("model")
    assert r.pspec(("embed", "mlp"), (2048, 5632)) == P(None, "model")
    assert r.pspec(("batch", None), (256, 4096)) == P("data")


def test_multipod_batch_axes():
    r = MeshRules.create(MESH3)
    assert r.pspec(("batch", None), (256, 4096)) == P(("pod", "data"))


def test_divisibility_fallback_kv_heads():
    r = MeshRules.create(MESH)
    # kv=4 not divisible by model=16 -> replicate
    assert r.pspec(("embed", "kv_heads", None), (2048, 4, 64)) == P()
    # q heads 32 divisible -> shard
    assert r.pspec(("embed", "heads", None), (2048, 32, 64)) == P(None, "model")


def test_divisibility_fallback_odd_vocab():
    r = MeshRules.create(MESH)
    assert r.pspec(("vocab", "embed"), (51865, 512)) == P()  # whisper vocab


def test_batch_fallback_for_batch_1():
    r = MeshRules.create(MESH3)
    assert r.pspec(("batch", None), (1, 1)) == P()


def test_no_axis_reuse_within_spec():
    r = MeshRules.create(MESH, overrides={"seq": ("model",)})
    s = r.pspec(("heads", "seq"), (32, 4096))
    # model used by heads; seq falls back to replication, never reused
    assert s == P("model")


def test_overrides_ep_mode():
    r = MeshRules.create(MESH, overrides={"expert": ("data",)})
    assert r.pspec(("expert", "embed", "mlp"), (256, 64, 2048)) == \
        P("data", None, "model")


@pytest.mark.slow
def test_mini_dryrun_8_devices(tmp_path):
    """Real lower+compile of the smoke model on 8 forced host devices:
    proves the sharding config is coherent, end to end, in miniature."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_model
        from repro.configs.base import TrainConfig, ShapeConfig
        from repro.common.pytree import abstract
        from repro.train.train_step import make_train_step
        from repro.train.optimizer import init_opt_state, opt_state_specs

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        m = smoke_model("gemma2-9b")
        m.mesh = mesh
        defs = m.param_defs()
        p_abs = abstract(defs)
        specs = m.param_specs()
        def shard(t):
            return jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
        o_abs = jax.eval_shape(lambda p: init_opt_state(p, keep_master=False), p_abs)
        o_specs = opt_state_specs(specs, defs, mesh, keep_master=False)
        tcfg = TrainConfig(microbatch=4)
        step = make_train_step(m, tcfg)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        b_specs = {"tokens": P("data", None)}
        with mesh:
            fn = jax.jit(step, in_shardings=(shard(specs), shard(o_specs), shard(b_specs)),
                         out_shardings=(shard(specs), shard(o_specs), None))
            compiled = fn.lower(p_abs, o_abs, batch).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):   # jax<=0.4.x: one dict per device
            ca = ca[0]
        print(json.dumps({"flops": ca.get("flops", 0.0),
                          "n_devices": mesh.devices.size}))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["n_devices"] == 8
    assert out["flops"] > 0
