"""Golden-equivalence scenarios, shared by tests/test_engine_equiv.py and
scripts/gen_engine_goldens.py.  The stored golden file was generated from
the PR-1 seed engine — keep these definitions bitwise-stable or re-baseline
(see the script's docstring)."""
from repro.core.collectives import allreduce_1d, alltoall, incast
from repro.core.engine import EngineConfig
from repro.core.topology import clos, single_switch


def scenarios():
    ss = single_switch(8)
    small = clos(n_racks=1, nodes_per_rack=2, gpus_per_node=4)
    mid = clos(n_racks=2, nodes_per_rack=2, gpus_per_node=8)
    yield ("incast_ss8", ss, incast(ss, list(range(1, 8)), 0, 10e6),
           ["pfc", "dcqcn", "dctcp"],
           EngineConfig(dt=1e-6, max_steps=1500, max_extends=5))
    yield ("ar1d_clos8", small, allreduce_1d(small, list(range(8)), 8e6),
           ["hpcc", "static_window", "timely"],
           EngineConfig(dt=1e-6, max_steps=1500, max_extends=2))
    yield ("a2a_clos32", mid, alltoall(mid, list(range(32)), 16e6),
           ["dcqcn"], EngineConfig(dt=2e-6, max_steps=1200, max_extends=1))
