"""GPipe stage-parallel primitive vs sequential reference (4 forced
host devices in a subprocess)."""
import os
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.slow
def test_gpipe_matches_sequential():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.pipeline import gpipe

        mesh = jax.make_mesh((4,), ("stage",))
        n_stages, n_micro, mb, d = 4, 8, 2, 16
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (n_stages, d, d)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

        def stage_fn(wi, h):
            return jnp.tanh(h @ wi)

        out = gpipe(stage_fn, w, x, mesh)
        # sequential reference
        ref = x
        for s in range(n_stages):
            ref = jnp.tanh(ref @ w[s])
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, err
        print("OK", err)
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
