"""Optimizer math, ZeRO-1 specs, data determinism, prefetcher."""
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import TrainConfig
from repro.data.pipeline import Prefetcher, dlrm_batch, lm_batch
from repro.train.optimizer import (adamw_update, init_opt_state,
                                   lr_schedule, zero1_spec)


def test_adamw_first_step_matches_reference():
    tcfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=1,
                       total_steps=10, grad_clip=1e9)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, -0.5])}
    st = init_opt_state(p, keep_master=False)
    p2, st2, m = adamw_update(p, g, st, tcfg)
    # bias-corrected adam first step = -lr * sign-ish(g)
    lr = float(lr_schedule(tcfg, 1))
    expect = np.asarray([1.0, -2.0]) - lr * np.asarray([0.5, -0.5]) / (
        np.abs([0.5, -0.5]) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-4)


def test_grad_clip_applies():
    tcfg = TrainConfig(learning_rate=1.0, weight_decay=0.0, warmup_steps=1,
                       total_steps=10, grad_clip=0.1)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full((4,), 100.0)}
    st = init_opt_state(p, keep_master=False)
    _, _, m = adamw_update(p, g, st, tcfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_master_weights_roundtrip_bf16():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=100)
    p = {"w": jnp.asarray([1.0, 2.0], jnp.bfloat16)}
    st = init_opt_state(p, keep_master=True)
    g = {"w": jnp.asarray([1e-3, -1e-3], jnp.float32)}
    p2, st2, _ = adamw_update(p, g, st, tcfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert st2["master"]["w"].dtype == jnp.float32
    # master accumulates sub-bf16 updates
    assert float(st2["master"]["w"][0]) != 1.0


def test_lr_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(tcfg, 1)) < 0.2
    assert float(lr_schedule(tcfg, 10)) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_schedule(tcfg, 100)) < 0.2


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_zero1_spec_adds_data_axis():
    s = zero1_spec(P(None, "model"), (4096, 1024), _FakeMesh())
    assert s == P("data", "model")


def test_zero1_spec_skips_indivisible():
    s = zero1_spec(P(None,), (7,), _FakeMesh())
    assert s == P(None,)


def test_zero1_spec_no_double_assign():
    s = zero1_spec(P("data", None), (64, 64), _FakeMesh())
    assert s == P("data", None)


def test_lm_batch_deterministic():
    a = lm_batch(0, 5, 4, 16, 1000)
    b = lm_batch(0, 5, 4, 16, 1000)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm_batch(0, 6, 4, 16, 1000)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_dlrm_batch_label_learnable():
    from repro.configs import smoke_config
    cfg = smoke_config("dlrm")
    b = dlrm_batch(0, 0, 256, cfg)
    assert 0.2 < b["label"].mean() < 0.8  # non-degenerate


def test_prefetcher_order_and_close():
    pf = Prefetcher(lambda s: {"step": s}, start_step=3, depth=2)
    got = [next(pf)["step"] for _ in range(4)]
    pf.close()
    assert got == [3, 4, 5, 6]
