"""Block-level consistency: mamba2 chunked-vs-recurrent, rwkv6 scan,
MoE impls, MLA absorbed-decode vs train form."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import rwkv as RWKV
from repro.models import ssm as SSM
from repro.common.pytree import materialize


def test_mamba2_chunked_matches_stepwise(key):
    cfg = smoke_config("zamba2-1.2b")
    p = materialize(SSM.mamba2_defs(cfg), key)
    B, S = 2, 16
    x = 0.5 * jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    y_chunk, _ = SSM.mamba2_apply(p, x, dataclasses.replace(cfg, ssm_chunk=8))
    # stepwise decode over the same tokens
    state = {"conv": jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state)),
             "ssm": jnp.zeros((B, cfg.ssm_nheads, cfg.ssm_state, cfg.ssm_headdim))}
    outs = []
    for t in range(S):
        o, state = SSM.mamba2_apply(p, x[:, t:t + 1], cfg, state)
        outs.append(o)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_step, np.float32), rtol=2e-3, atol=2e-3)


def test_rwkv6_scan_matches_stepwise(key):
    cfg = smoke_config("rwkv6-3b")
    defs = RWKV.rwkv6_defs(cfg)
    p = materialize(defs, key)
    B, S = 2, 12
    x = 0.5 * jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    y_all, _ = RWKV.rwkv6_time_mix(p["time"], x, cfg, None)
    state = {"S": jnp.zeros((B, cfg.n_heads, cfg.d_model // cfg.n_heads,
                             cfg.d_model // cfg.n_heads)),
             "tok": jnp.zeros((B, cfg.d_model))}
    outs = []
    for t in range(S):
        o, state = RWKV.rwkv6_time_mix(p["time"], x[:, t:t + 1], cfg, state)
        outs.append(o)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_all, np.float32),
                               np.asarray(y_step, np.float32), rtol=2e-3, atol=2e-3)


def test_moe_dense_gates_sum_to_one(key):
    cfg = smoke_config("deepseek-v3-671b")
    p = materialize(MOE.moe_defs(cfg), key)
    x = jax.random.normal(key, (16, cfg.d_model), jnp.float32)
    gates, idx = MOE._router(p["router"], x, cfg)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < cfg.n_experts


def test_moe_capacity_scatter_roundtrip(key):
    """scatter -> gather with ample capacity is the identity (x gates)."""
    T, k, E, cap, D = 10, 2, 4, 8, 6
    idx = jax.random.randint(key, (T, k), 0, E)
    x = jax.random.normal(key, (T, D), jnp.float32)
    pos, kept = MOE._positions(idx, jnp.ones_like(idx, bool), E, cap)
    assert bool(kept.all())
    buf = MOE._scatter_slots(x, idx, pos, kept, E, cap)
    ones = jnp.ones((T, k), jnp.float32)
    back = MOE._gather_slots(buf, idx, pos, kept, ones)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x) * np.asarray(
        jnp.ones((T, 1))) * k if False else np.asarray(x) * k, rtol=1e-6)


def test_moe_capacity_drops_overflow(key):
    T, k, E, cap = 16, 2, 2, 3
    idx = jnp.zeros((T, k), jnp.int32)  # all to expert 0 -> overflow
    pos, kept = MOE._positions(idx, jnp.ones_like(idx, bool), E, cap)
    assert int(kept.sum()) == cap


def test_mla_absorbed_decode_matches_train_form(key):
    cfg = smoke_config("deepseek-v3-671b")
    p = materialize(MLA.mla_defs(cfg), key)
    B, S = 2, 8
    x = 0.5 * jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y_train = MLA.mla_train(p, x, cfg, pos)
    # decode the last token against the latent cache of the first S-1
    c, pe = MLA.mla_prefill_cache(p, x, cfg, pos)
    y_dec = MLA.mla_decode(p, x[:, -1:], cfg, c, pe, length=jnp.asarray(S - 1))
    np.testing.assert_allclose(np.asarray(y_dec[:, 0], np.float32),
                               np.asarray(y_train[:, -1], np.float32),
                               rtol=2e-3, atol=2e-3)
