"""End-to-end behaviour tests: train-to-learn, serve, workload sim,
autotune, prediction bridge."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_model
from repro.configs.base import TrainConfig
from repro.data.pipeline import lm_batch, dlrm_batch
from repro.train.train_step import init_train_state, make_train_step


def test_lm_end_to_end_learns(key):
    m = smoke_model("tinyllama-1.1b")
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=5, total_steps=60)
    params, opt = init_train_state(m, key, tcfg)
    step = jax.jit(make_train_step(m, tcfg))
    losses = []
    for i in range(40):
        b = {k: jnp.asarray(v) for k, v in lm_batch(0, i, 8, 64, m.cfg.vocab).items()}
        params, opt, metrics = step(params, opt, b)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.4, (losses[0], losses[-1])


def test_grad_accumulation_equivalent(key):
    m = smoke_model("tinyllama-1.1b")
    b = {k: jnp.asarray(v) for k, v in lm_batch(0, 0, 8, 32, m.cfg.vocab).items()}
    t1 = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
    t2 = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10, microbatch=2)
    p1, o1 = init_train_state(m, key, t1)
    p2 = jax.tree.map(lambda x: x, p1)
    o2 = jax.tree.map(lambda x: x, o1)
    p1, _, m1 = jax.jit(make_train_step(m, t1))(p1, o1, b)
    p2, _, m2 = jax.jit(make_train_step(m, t2))(p2, o2, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-3)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]),
                               rtol=1e-4)
    # Adam's first step is sign-like: tiny grad differences flip the +-lr
    # direction for near-zero entries, so params can differ by up to 2*lr
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), atol=2.5e-3)


def test_dlrm_trains(key):
    m = smoke_model("dlrm")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=80)
    params, opt = init_train_state(m, key, tcfg)
    step = jax.jit(make_train_step(m, tcfg))
    losses = []
    for i in range(60):
        b = {k: jnp.asarray(v) for k, v in dlrm_batch(0, i, 64, m.cfg).items()}
        params, opt, metrics = step(params, opt, b)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < 0.65, losses[-1]  # below chance (0.693)


def test_serve_engine_batches(key):
    from repro.serve.engine import Request, ServeEngine
    m = smoke_model("tinyllama-1.1b")
    params = m.init(key)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, m.cfg.vocab, 12, dtype=np.int32), 4)
            for i in range(6)]
    eng = ServeEngine(m, params, batch_slots=4, max_len=32)
    results = eng.run(reqs)
    assert len(results) == 6
    for r in results:
        assert r.tokens.shape == (4,)
        assert np.all((0 <= r.tokens) & (r.tokens < m.cfg.vocab))


def test_autotune_improves_dcqcn():
    from repro.core.autotune import autotune
    from repro.core.cc import make_dcqcn
    from repro.core.collectives import incast
    from repro.core.engine import EngineConfig
    from repro.core.topology import single_switch
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 4e6)
    res = autotune(topo, sched, make_dcqcn(), ["rai_frac", "g"],
                   steps=4, lr=0.2,
                   cfg=EngineConfig(dt=2e-6, max_steps=900, max_extends=0))
    assert res.tuned_cost <= res.baseline_cost * 1.001
    assert len(res.history) == 4


def test_predict_bridge_runs():
    from repro.core.hlo_comm import CollectiveOp
    from repro.core.predict import predict_policies
    from repro.core.topology import clos
    ops = [CollectiveOp("all-reduce", 64e6, 16, 16),
           CollectiveOp("all-to-all", 16e6, 16, 16)]
    topo = clos(n_racks=1, nodes_per_rack=2, gpus_per_node=8)
    reps = predict_policies(ops, (16, 16), [0, 1], policies=("pfc", "dcqcn"),
                            topo=topo)
    assert all(r.finished for r in reps)
    assert all(r.comm_time > 0 for r in reps)
