"""The reproduction gate: the paper's findings F1-F6 (DESIGN.md §1) at
test-sized scale.  Heavier full-scale runs live in benchmarks/."""
import pytest

from repro.core.cc import get_policy
from repro.core.collectives import allreduce_1d, allreduce_2d, alltoall, incast
from repro.core.engine import EngineConfig, simulate
from repro.core.topology import clos, single_switch
from repro.core.workload import DLRMCommSpec, simulate_dlrm_iteration

CFG = EngineConfig(dt=1e-6, max_steps=2000, max_extends=5)


@pytest.fixture(scope="module")
def incast_results():
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 10e6)
    return {name: simulate(topo, sched, get_policy(name), CFG)
            for name in ("pfc", "dcqcn", "dctcp", "timely", "hpcc",
                         "hpcc_pint", "static_window")}


def test_f1_pfc_queue_rides_threshold_many_pauses(incast_results):
    r = incast_results["pfc"]
    q = r.dev_queue[:, 8]
    assert q.max() > 5e6            # queue held high
    assert r.pause_count.sum() > 50  # "a lot of PFCs"
    assert r.completion_time <= 7 * 10e6 / 25e9 * 1.01  # optimal for long flows


def test_f1_ccs_eliminate_pauses(incast_results):
    for name in ("dcqcn", "dctcp", "timely", "hpcc", "static_window"):
        assert incast_results[name].pause_count.sum() == 0, name


def test_f1_dctcp_drains_queue(incast_results):
    q = incast_results["dctcp"].dev_queue[:, 8]
    assert q.max() < 1e6  # small stable queue after initial buildup


def test_f1_timely_overthrottles(incast_results):
    """TIMELY: lowest queues but worst latency (paper Fig 3 discussion)."""
    t = incast_results["timely"]
    others = [incast_results[n].completion_time
              for n in ("pfc", "dcqcn", "dctcp", "hpcc", "static_window")]
    assert t.completion_time > max(others)
    assert t.dev_queue[:, 8].max() < incast_results["pfc"].dev_queue[:, 8].max()


def test_f1_hpcc_near_zero_queue(incast_results):
    q = incast_results["hpcc"].dev_queue[:, 8]
    assert q.max() < 0.5e6


@pytest.fixture(scope="module")
def clos_topo():
    return clos(n_racks=2, nodes_per_rack=2, gpus_per_node=4)  # 16 GPUs


def test_f2_single_switch_collectives_no_congestion():
    topo = single_switch(8)
    gpus = list(range(8))
    times = {}
    for name in ("pfc", "dcqcn", "dctcp", "hpcc"):
        r = simulate(topo, alltoall(topo, gpus, 10e6), get_policy(name), CFG)
        assert r.finished
        assert r.pause_count.sum() == 0, name       # no congestion -> no PFCs
        times[name] = r.completion_time
    spread = max(times.values()) / min(times.values()) - 1
    assert spread < 0.12, times                      # all CCs ~equal


def test_f3_four_chunks_four_peaks(clos_topo):
    gpus = list(range(16))
    r = simulate(clos_topo, alltoall(clos_topo, gpus, 64e6, n_chunks=4),
                 get_policy("pfc"), CFG)
    assert r.finished
    # four chunk groups complete strictly in order
    gt = r.group_time
    assert all(gt[i] < gt[i + 1] for i in range(3))


def test_f4_2d_much_faster_and_fewer_pauses(clos_topo):
    gpus = list(range(16))
    r1 = simulate(clos_topo, allreduce_1d(clos_topo, gpus, 128e6),
                  get_policy("pfc"), CFG)
    r2 = simulate(clos_topo, allreduce_2d(clos_topo, gpus, 128e6),
                  get_policy("pfc"), CFG)
    assert r1.finished and r2.finished
    assert r2.completion_time < r1.completion_time / 2
    assert r2.pause_count.sum() < r1.pause_count.sum() / 2


def test_f5_dlrm_e2e_ordering(clos_topo):
    gpus = list(range(16))
    cfg = EngineConfig(dt=2e-6, max_steps=2000, max_extends=5)
    reps = {name: simulate_dlrm_iteration(clos_topo, gpus, get_policy(name),
                                          comm=DLRMCommSpec(), cfg=cfg)
            for name in ("pfc", "dcqcn", "dctcp", "hpcc", "static_window")}
    for name, rep in reps.items():
        assert rep.finished, name
    base = reps["pfc"].iteration_time
    # paper: PFC-only gives best-or-equal e2e; HPCC hurt by INT overhead
    assert reps["hpcc"].iteration_time >= base
    assert reps["dctcp"].iteration_time <= base * 1.1
    assert reps["dcqcn"].iteration_time <= base * 1.25


def test_f6_static_window_matches_pfc_with_no_pauses(clos_topo):
    """The paper's §IV-E proposed CC, implemented (beyond-paper)."""
    gpus = list(range(16))
    cfg = EngineConfig(dt=2e-6, max_steps=2000, max_extends=5)
    pfc = simulate_dlrm_iteration(clos_topo, gpus, get_policy("pfc"), cfg=cfg)
    sw = simulate_dlrm_iteration(clos_topo, gpus, get_policy("static_window"),
                                 cfg=cfg)
    assert sw.finished
    assert sw.iteration_time <= pfc.iteration_time * 1.1   # same performance
    assert sw.pfc_pauses == 0                              # ~zero PAUSE frames
    assert pfc.pfc_pauses > 0
