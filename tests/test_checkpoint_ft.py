"""Checkpoint atomicity/roundtrip + fault-tolerance runtime behaviour."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import (AsyncCheckpointer, gc_old, latest_step,
                                   restore, save)
from repro.configs import smoke_model
from repro.configs.base import TrainConfig
from repro.data.pipeline import lm_batch
from repro.ft.fault_tolerance import (FailureInjector, RunnerConfig,
                                      StragglerDetector, TrainRunner)
from repro.train.train_step import init_train_state, make_train_step


def _tree(key):
    return {"a": jax.random.normal(key, (8, 4)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32), "d": jnp.float32(3.5)}}


def test_roundtrip(tmp_path, key):
    t = _tree(key)
    save(str(tmp_path), 7, t)
    assert latest_step(str(tmp_path)) == 7
    t2, meta = restore(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_tmp_left(tmp_path, key):
    save(str(tmp_path), 1, _tree(key))
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_gc_keeps_latest(tmp_path, key):
    t = _tree(key)
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, t)
    gc_old(str(tmp_path), keep=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [4, 5]


def test_async_checkpointer(tmp_path, key):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree(key)
    ck.save(3, t, extra_meta={"next_step": 3})
    ck.wait()
    t2, meta = restore(str(tmp_path), 3, t)
    assert meta["next_step"] == 3


def _mk_runner(tmp_path, fail_at=(), ckpt_every=5):
    m = smoke_model("tinyllama-1.1b")
    tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=2, total_steps=30)
    params, opt = init_train_state(m, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(make_train_step(m, tcfg))

    def make_batch(s):
        return {k: jnp.asarray(v) for k, v in
                lm_batch(0, s, 4, 32, m.cfg.vocab).items()}

    runner = TrainRunner(RunnerConfig(str(tmp_path), checkpoint_every=ckpt_every),
                         step, make_batch, injector=FailureInjector(fail_at))
    return runner, params, opt


def test_restart_recovers_and_matches_uninterrupted(tmp_path):
    # run A: uninterrupted 20 steps
    ra, pa, oa = _mk_runner(tmp_path / "a")
    pa, oa = ra.run(pa, oa, 20)
    # run B: failure injected at step 13 -> restart from checkpoint at 10
    rb, pb, ob = _mk_runner(tmp_path / "b", fail_at=(13,))
    pb, ob = rb.run(pb, ob, 20)
    assert rb.restarts == 1
    # deterministic data + restart => identical final params
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5, atol=1e-6)


def test_straggler_detector_flags_slow_steps():
    det = StragglerDetector(window=20, z_threshold=3.0, patience=2)
    for i in range(30):
        det.observe(i, 0.1 + 0.001 * (i % 3))
    for i in range(30, 34):
        det.observe(i, 1.5)  # sustained straggler
    assert det.flagged, "sustained slow steps must be flagged"


def test_straggler_detector_ignores_one_off():
    det = StragglerDetector(window=20, z_threshold=3.0, patience=3)
    for i in range(25):
        det.observe(i, 0.1)
    det.observe(25, 2.0)  # single spike (e.g. checkpoint write)
    for i in range(26, 30):
        det.observe(i, 0.1)
    assert not det.flagged


def test_elastic_restore_between_meshes(tmp_path, key):
    """Checkpoint written flat restores onto any device layout (1-dev CPU
    degenerate case exercises the device_put path)."""
    from jax.sharding import Mesh, PartitionSpec as P
    t = {"w": jax.random.normal(key, (16, 8))}
    save(str(tmp_path), 1, t, specs={"w": P(None, None)})
    mesh = Mesh(np.asarray(jax.devices()).reshape(1, 1), ("data", "model"))
    t2, _ = restore(str(tmp_path), 1, t, mesh=mesh, specs={"w": P("data", "model")})
    np.testing.assert_array_equal(np.asarray(t["w"]), np.asarray(t2["w"]))


def test_bf16_checkpoint_roundtrip(tmp_path, key):
    """bf16 leaves must survive save/restore (numpy has no native bf16)."""
    t = {"w": jax.random.normal(key, (8, 4)).astype(jnp.bfloat16),
         "b": jnp.arange(4, dtype=jnp.int32)}
    save(str(tmp_path), 2, t)
    t2, _ = restore(str(tmp_path), 2, t)
    assert t2["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(t["w"], np.float32),
                                  np.asarray(t2["w"], np.float32))
