"""The learned-CC subsystem (``repro.learn``): gradient correctness of
``soft_cost`` through both scan paths, the ``mlp`` policy's integration
contracts (registry, kernels, ``stack_policies``), and the trainer's
robustness guarantees (determinism, resume, non-finite guard).

The whole suite carries the ``learn`` marker (``pytest -m learn``).

Gradient tests run on a lossy go-back-N incast: the fluid model's
``min()`` delivery dynamics make the soft cost *exactly* flat wherever
rate/window have surplus in a healthy fabric (any allocation that keeps
the bottleneck busy delivers the same integral), so a healthy scenario
has no finite-differencable signal — loss recovery puts a live
rate/goodput trade-off into the objective.
"""
import json
import math
import os

import jax
import numpy as np
import pytest

from repro.core import cc
from repro.core.engine import EngineConfig, Simulator, _as_fabric
from repro.core.faults import FaultSpec
from repro.core.scenario import IncastSpec, ScenarioSpec
from repro.core.sweep import SweepRunner
from repro.learn.net import WEIGHT_KEYS, init_weights, make_mlp
from repro.learn.train import (Task, TrainConfig, _single, load_checkpoint,
                               save_checkpoint, train)

pytestmark = pytest.mark.learn

CFG = EngineConfig(dt=2e-6, max_steps=900, max_extends=0, queue_stride=0)

_CASE = {}


def _lossy_case():
    """One cached lossy-GBN incast at a mid-binding operating point (both
    heads active, away from every clip bound)."""
    if not _CASE:
        w = init_weights(0)
        w["b2_0"] = -4.0
        w["b2_1"] = 0.0
        pol = make_mlp(weights=w)
        spec = ScenarioSpec(_single(8), IncastSpec(7, 2e6), "mlp",
                            fault_spec=FaultSpec.lossy_roce(2e-3, "gbn"))
        topo, sched, _ = spec.build()
        sim = Simulator(topo, sched, pol, CFG, fault_spec=spec.fault_spec)
        _CASE["params"] = dict(pol.params)
        _CASE["fab"] = _as_fabric(None, CFG)
        _CASE["cost"] = jax.jit(sim.soft_cost_fn())
        _CASE["cost_remat"] = jax.jit(sim.soft_cost_fn(remat=True))
        _CASE["sim"] = sim
    return _CASE


# ---------------------------------------------------------------------------
# registry / kernel integration
# ---------------------------------------------------------------------------

def test_mlp_registered_kernel_eligible():
    pol = cc.get_policy("mlp")
    assert "mlp" in cc.ALL_POLICIES
    assert pol.loss_aware
    # dict-of-(F,) state + pure elementwise update: rides the fused
    # Pallas engine-step tiles (the kernel-vs-ref pin itself lives in
    # test_engine_step_kernel.py, parametrized over the whole registry)
    assert cc.kernel_eligible(pol)


def test_make_mlp_rejects_bad_weight_sets():
    with pytest.raises(ValueError):
        make_mlp(weights={"nope": 1.0})
    partial = {k: 0.0 for k in list(WEIGHT_KEYS)[:-1]}
    with pytest.raises(ValueError):
        make_mlp(weights=partial)


def test_stack_policies_with_mlp():
    """A (classical, learned) tuple stacks into one batched dispatch and
    each lane reproduces its solo run."""
    spec = ScenarioSpec(_single(8), IncastSpec(7, 2e6), "mlp")
    topo, sched, _ = spec.build()
    runner = SweepRunner(CFG)
    batch = runner.run_policy_axis(topo, sched, ["dcqcn", "mlp"], cfg=CFG)
    assert batch.policy_axis == ("dcqcn", "mlp")
    assert batch.lane_status() == ["ok", "ok"]
    solo = Simulator(topo, sched, cc.get_policy("mlp"), CFG).run()
    np.testing.assert_allclose(batch.completion_time[1],
                               float(solo.completion_time), rtol=1e-5)


# ---------------------------------------------------------------------------
# gradient correctness (satellite: FD vs autodiff, both scan paths)
# ---------------------------------------------------------------------------

def test_remat_forward_bitwise_identical():
    c = _lossy_case()
    a = float(c["cost"](c["params"], c["fab"]))
    b = float(c["cost_remat"](c["params"], c["fab"]))
    assert a == b          # jax.checkpoint must not change the forward


def test_remat_rejects_early_exit():
    sim = _lossy_case()["sim"]
    from repro.core.engine import _make_run
    with pytest.raises(ValueError, match="remat"):
        _make_run(sim.policy, sim.cfg, sim.plan, early_exit=True,
                  remat=True)


def test_grad_remat_matches_nonremat():
    c = _lossy_case()
    g = jax.grad(lambda p: c["cost"](p, c["fab"]))(c["params"])
    gr = jax.grad(lambda p: c["cost_remat"](p, c["fab"]))(c["params"])
    for k in g:
        np.testing.assert_allclose(float(g[k]), float(gr[k]), rtol=1e-4,
                                   err_msg=k)
    gf = jax.grad(lambda f: c["cost"](c["params"], f))(c["fab"])
    gfr = jax.grad(lambda f: c["cost_remat"](c["params"], f))(c["fab"])
    for k in ("kmin", "kmax", "pmax", "xoff", "xon"):
        np.testing.assert_allclose(float(getattr(gf, k)),
                                   float(getattr(gfr, k)), rtol=1e-4,
                                   err_msg=k)


@pytest.mark.parametrize("remat", [False, True])
def test_fd_gradient_cc_params(remat):
    """Central finite differences confirm the autodiff gradient w.r.t.
    the policy weights (loose tolerance: f32 forward, 900-step unroll)."""
    c = _lossy_case()
    cost = c["cost_remat"] if remat else c["cost"]
    g = jax.grad(lambda p: cost(p, c["fab"]))(c["params"])
    for key, eps in (("b2_0", 0.05), ("b2_1", 0.05)):
        pp = {**c["params"], key: c["params"][key] + eps}
        pm = {**c["params"], key: c["params"][key] - eps}
        fd = (float(cost(pp, c["fab"])) - float(cost(pm, c["fab"]))) \
            / (2 * eps)
        ad = float(g[key])
        assert math.copysign(1, fd) == math.copysign(1, ad), key
        np.testing.assert_allclose(ad, fd, rtol=0.3, err_msg=key)


@pytest.mark.parametrize("remat", [False, True])
def test_fd_gradient_fabric_params(remat):
    """Same FD pin for the FabricParams leaves (pmax: the ECN marking
    ceiling drives the policy's rate response, which trades goodput
    against loss recovery)."""
    c = _lossy_case()
    cost = c["cost_remat"] if remat else c["cost"]
    gf = jax.grad(lambda f: cost(c["params"], f))(c["fab"])
    eps = 0.2
    fd = (float(cost(c["params"], c["fab"].replace(pmax=c["fab"].pmax + eps)))
          - float(cost(c["params"],
                       c["fab"].replace(pmax=c["fab"].pmax - eps)))) \
        / (2 * eps)
    ad = float(gf.pmax)
    assert fd != 0.0
    assert math.copysign(1, fd) == math.copysign(1, ad)
    np.testing.assert_allclose(ad, fd, rtol=0.3)


# ---------------------------------------------------------------------------
# trainer robustness (fake tasks: exact quadratic bowls, no simulator)
# ---------------------------------------------------------------------------

def _quad_task(name="quad", weight=1.0, nan_at=None):
    """A deterministic quadratic-bowl task; ``nan_at=k`` poisons the k-th
    evaluation (1-based) the way a diverged simulation would."""
    target = {k: 0.3 * ((i % 5) - 2) for i, k in enumerate(WEIGHT_KEYS)}
    calls = {"n": 0}

    def vg(w):
        calls["n"] += 1
        if nan_at is not None and calls["n"] == nan_at:
            return float("nan"), {k: 0.0 for k in WEIGHT_KEYS}
        cst = sum((float(w[k]) - target[k]) ** 2 for k in WEIGHT_KEYS)
        grd = {k: 2 * (float(w[k]) - target[k]) for k in WEIGHT_KEYS}
        return cst, grd

    return Task(name=name, weight=weight, vg=vg)


def test_trainer_deterministic_bitwise():
    cfg = TrainConfig(steps=4, lr=0.05, seed=7)
    r1 = train(cfg, tasks=[_quad_task()])
    r2 = train(cfg, tasks=[_quad_task()])
    assert r1.weights == r2.weights          # bitwise: python-float Adam
    assert [h["loss"] for h in r1.history] \
        == [h["loss"] for h in r2.history]


def test_trainer_seed_changes_init():
    assert init_weights(0) != init_weights(1)
    assert init_weights(3) == init_weights(3)


def test_trainer_loss_decreases_on_bowl():
    res = train(TrainConfig(steps=60, lr=0.1), tasks=[_quad_task()])
    losses = [h["loss"] for h in res.history]
    assert losses[-1] < 0.1 * losses[0]
    assert res.final_loss == losses[-1]


def test_trainer_resume_bitwise(tmp_path):
    ck = str(tmp_path / "ck.json")
    straight = train(TrainConfig(steps=6), tasks=[_quad_task()])
    train(TrainConfig(steps=3), tasks=[_quad_task()], checkpoint_path=ck)
    resumed = train(TrainConfig(steps=6), tasks=[_quad_task()], resume=ck)
    assert resumed.weights == straight.weights
    assert len(resumed.history) == 6
    assert [h["loss"] for h in resumed.history] \
        == [h["loss"] for h in straight.history]


def test_trainer_resume_rejects_seed_mismatch(tmp_path):
    ck = str(tmp_path / "ck.json")
    train(TrainConfig(steps=1, seed=0), tasks=[_quad_task()],
          checkpoint_path=ck)
    with pytest.raises(ValueError, match="seed"):
        train(TrainConfig(steps=2, seed=1), tasks=[_quad_task()], resume=ck)


def test_trainer_nonfinite_guard():
    """A poisoned step freezes weights AND optimizer moments (mirroring
    autotune's non-finite member guard) and is recorded in history."""
    cfg = TrainConfig(steps=2, lr=0.05, seed=3)
    poisoned = train(cfg, tasks=[_quad_task(nan_at=2)])
    assert [h["nonfinite"] for h in poisoned.history] == [False, True]
    assert math.isnan(poisoned.history[1]["loss"])
    clean_1step = train(TrainConfig(steps=1, lr=0.05, seed=3),
                        tasks=[_quad_task()])
    # step 2 was frozen, so 2 poisoned steps == 1 clean step, bitwise
    assert poisoned.weights == clean_1step.weights


def test_checkpoint_roundtrip_exact(tmp_path):
    ck = str(tmp_path / "ck.json")
    state = {"seed": 0, "step": 3, "weights": {"a": 0.1 + 0.2},
             "m": {"a": -1e-17}, "v": {"a": 2.0 ** -40},
             "history": [{"loss": 1.0}], "baselines": {"t": 3.3e-4}}
    save_checkpoint(ck, state)
    assert load_checkpoint(ck) == state  # float64 JSON repr is exact


def test_weights_projected_into_bounds():
    wild = {k: 100.0 for k in WEIGHT_KEYS}
    res = train(TrainConfig(steps=1), tasks=[_quad_task()],
                resume={"seed": 0, "step": 0, "weights": wild,
                        "m": {k: 0.0 for k in WEIGHT_KEYS},
                        "v": {k: 0.0 for k in WEIGHT_KEYS},
                        "history": [], "baselines": {}})
    assert all(-8.0 <= v <= 8.0 for v in res.weights.values())


# ---------------------------------------------------------------------------
# end-to-end: a real gradient-through-sim descent
# ---------------------------------------------------------------------------

def test_train_through_simulator_decreases_loss():
    """Three Adam steps through the real (remat) simulator from the
    binding-regime init strictly decrease the normalized soft cost."""
    from repro.learn.train import make_task
    cfg = TrainConfig(steps=3, lr=0.05)
    task = make_task(ScenarioSpec(_single(8), IncastSpec(7, 1e6), "mlp",
                                  name="t"),
                     engine_cfg=EngineConfig(dt=2e-6, max_steps=900,
                                             max_extends=0, queue_stride=0),
                     corners=(None,), train_cfg=cfg)
    res = train(cfg, tasks=[task])
    losses = [h["loss"] for h in res.history]
    assert all(math.isfinite(x) for x in losses)
    assert losses[-1] < losses[0]
    assert not any(h["nonfinite"] for h in res.history)


def test_shipped_weights_file_contract():
    """If the trained-weights artifact is committed it must cover every
    weight key with finite in-bounds values (default_weights() refuses a
    stale/partial file)."""
    path = os.path.join(os.path.dirname(__file__), os.pardir, "src",
                        "repro", "learn", "mlp_weights.json")
    if not os.path.exists(path):
        pytest.skip("no trained weights committed yet")
    blob = json.load(open(path))
    w = blob["weights"]
    assert set(w) == set(WEIGHT_KEYS)
    assert all(math.isfinite(v) and -8.0 <= v <= 8.0 for v in w.values())
