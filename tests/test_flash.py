"""Flash-attention custom VJP vs dense reference (fwd + grads)."""
import jax
import numpy as np
import pytest

from repro.models.flash import flash_attention
from repro.models.layers import dense_attention


@pytest.mark.parametrize("S,bq,bk", [(256, 64, 64), (512, 128, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_forward(S, bq, bk, causal, key):
    q = jax.random.normal(key, (2, S, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, S, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, S, 2, 32))
    out = flash_attention(q, k, v, causal, bq, bk)
    ref = dense_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_grads_match_dense(key):
    B, S, Hq, Hkv, D = 2, 256, 4, 2, 32
    q = jax.random.normal(key, (B, S, Hq, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D))

    def loss_flash(q, k, v):
        return (flash_attention(q, k, v, True, 64, 64) ** 2).sum()

    def loss_dense(q, k, v):
        return (dense_attention(q, k, v, causal=True) ** 2).sum()

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_model_trains_with_flash(key):
    """End-to-end grads through a flash-enabled reduced model."""
    import dataclasses
    from repro.configs import smoke_config
    from repro.models.model_api import Model
    # S must exceed the dense cutoff (1024) to exercise the flash path
    cfg = dataclasses.replace(smoke_config("tinyllama-1.1b"),
                              flash_attention=True, block_q=256, block_k=256)
    m = Model(cfg)
    params = m.init(key)
    batch = {"tokens": jax.random.randint(key, (1, 2048), 0, cfg.vocab)}
    loss, g = jax.jit(jax.value_and_grad(m.loss))(params, batch)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))
