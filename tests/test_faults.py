"""Fault-injection layer: lossy RoCE (IRN vs go-back-N recovery), link
degradation/flaps, ECN/PFC misconfiguration, and the run-health machinery
(pause-storm + pause-cycle deadlock detection, divergence lane isolation,
extend-exhausted reporting).

The whole suite carries the ``fault`` marker (``pytest -m fault``).

The first tests pin the layer's central contract: the all-defaults
``FaultSpec`` is *statically* inert — the engine compiles the historical
fault-free step for it, so lossless results stay bitwise-identical to the
PR-2 goldens.
"""
import json
import os
import warnings

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.cc import get_policy
from repro.core.collectives import Schedule, incast
from repro.core.engine import EngineConfig, FabricParams, Simulator, simulate
from repro.core.faults import (FAULT_PARAM_SPECS, RECOVERY_MODES, FaultSpec,
                               is_faulty)
from repro.core.scenario import (CollectiveSpec, FabricSpec, IncastSpec,
                                 ScenarioSpec)
from repro.core.sweep import SweepRunner, reset_unhealthy_warnings
from repro.core.topology import (NIC_BW, NIC_LAT, SWITCH_BUF, _Builder,
                                 single_switch)

pytestmark = pytest.mark.fault


@pytest.fixture(autouse=True)
def _rearm_unhealthy_warning():
    # the unhealthy-lane RuntimeWarning is deduplicated process-wide;
    # re-arm it so each pytest.warns assertion here sees a fresh warning
    # regardless of what ran before
    reset_unhealthy_warnings()

GOLD = json.load(open(os.path.join(os.path.dirname(__file__), "golden",
                                   "engine_seed.json")))


def _incast_case(size=5e6):
    topo = single_switch(8)
    return topo, incast(topo, list(range(1, 8)), 0, size)


def _cfg(**kw):
    kw.setdefault("dt", 1e-6)
    kw.setdefault("max_steps", 1500)
    kw.setdefault("max_extends", 3)
    kw.setdefault("queue_stride", 0)
    return EngineConfig(**kw)


def _quiet_run(sim, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return sim.run(**kw)


# ---------------------------------------------------------------------------
# the central contract: defaults are statically inert
# ---------------------------------------------------------------------------

def test_default_faultspec_is_statically_inert():
    assert not is_faulty(FaultSpec())
    assert is_faulty(FaultSpec(loss_rate=1e-4))
    assert is_faulty(FaultSpec(pfc_on=0.0))
    # a per-class array equal to the default everywhere is still inert
    assert not is_faulty(FaultSpec().with_class(loss_rate={}))
    assert is_faulty(FaultSpec().with_class(loss_rate={"spine_down": 1e-3}))


def test_explicit_default_spec_is_bitwise_identical():
    """run(fault_spec=FaultSpec()) must reuse the fault-free compile path
    and produce bitwise-identical arrays."""
    topo, sched = _incast_case()
    sim = Simulator(topo, sched, get_policy("dcqcn"), _cfg())
    base = sim.run()
    with_spec = sim.run(fault_spec=FaultSpec())
    assert np.array_equal(base.t_finish, with_spec.t_finish)
    assert np.array_equal(base.delivered, with_spec.delivered)
    assert np.array_equal(base.pause_count, with_spec.pause_count)
    assert with_spec.lost is None          # fault carry never materialized


def test_lossless_defaults_match_seed_goldens():
    """With the fault layer present but disabled, the engine still
    reproduces the PR-2 seed goldens."""
    topo, sched = _incast_case(10e6)
    g = GOLD["incast_ss8/pfc"]
    cfg = EngineConfig(dt=1e-6, max_steps=1500, max_extends=5)
    r = simulate(topo, sched, get_policy("pfc"), cfg, fault_spec=FaultSpec())
    assert r.finished == g["finished"]
    np.testing.assert_allclose(r.completion_time, g["completion_time"],
                               rtol=1e-5)
    t_gold = np.array([np.inf if v is None else v for v in g["t_finish"]])
    np.testing.assert_allclose(r.t_finish, t_gold, rtol=1e-5)


def test_faultspec_validation():
    with pytest.raises(ValueError, match="unknown recovery"):
        FaultSpec.lossy_roce(1e-3, recovery="arq")
    with pytest.raises(ValueError, match="unknown fault params"):
        FaultSpec.check_fields(["loss_rat"])
    assert RECOVERY_MODES == ("irn", "gbn")
    for k, s in FAULT_PARAM_SPECS.items():
        assert s.bounded, k
        assert s.lo <= s.default <= s.hi, k


# ---------------------------------------------------------------------------
# lossy RoCE: loss accounting + recovery models
# ---------------------------------------------------------------------------

def test_loss_slows_completion_and_gbn_worse_than_irn():
    topo, sched = _incast_case()
    sim = Simulator(topo, sched, get_policy("pfc"), _cfg())
    r0 = sim.run()
    r_irn = _quiet_run(sim, fault_spec=FaultSpec.lossy_roce(
        1e-3, "irn", pfc_on=True))
    r_gbn = _quiet_run(sim, fault_spec=FaultSpec.lossy_roce(
        1e-3, "gbn", pfc_on=True))
    assert r0.finished and r_irn.finished and r_gbn.finished
    assert r_irn.lost.sum() > 0
    # retransmits cost time; go-back-N resends ~half the in-flight window
    # per loss on top of IRN's selective retransmit, so it pays more
    assert r0.completion_time < r_irn.completion_time
    assert r_irn.completion_time < r_gbn.completion_time


def test_pfc_off_operating_point_disables_pausing():
    """lossy_roce defaults to pfc_on=False: the Mittal et al. regime —
    random loss, no PAUSE frames at all."""
    topo, sched = _incast_case()
    # tiny thresholds: the lossless run pauses heavily
    fab = FabricParams(xoff=100e3, xon=50e3)
    sim = Simulator(topo, sched, get_policy("pfc"), _cfg())
    r_on = sim.run(fabric_params=fab)
    assert r_on.pause_count.sum() > 0
    r_off = _quiet_run(sim, fabric_params=fab,
                       fault_spec=FaultSpec.lossy_roce(1e-4, "irn"))
    assert r_off.pause_count.sum() == 0
    assert r_off.finished


def test_loss_signal_reaches_loss_aware_policies():
    """A loss-aware policy (dcqcn) must react to the loss signal: the
    NACK-driven rate cuts make the lossy run measurably slower than the
    lossless one, beyond the raw retransmitted bytes."""
    topo, sched = _incast_case()
    sim = Simulator(topo, sched, get_policy("dcqcn"), _cfg())
    r0 = sim.run()
    r = _quiet_run(sim, fault_spec=FaultSpec.lossy_roce(
        1e-5, "irn", pfc_on=True))
    assert r.finished
    assert r.lost.sum() > 0
    assert r.completion_time > r0.completion_time


def test_ecn_misconfiguration_changes_dcqcn_behavior():
    """ecn_scale=0 breaks marking: DCQCN sees no congestion signal and
    the run degenerates to PFC-style behavior (different completion)."""
    topo, sched = _incast_case()
    sim = Simulator(topo, sched, get_policy("dcqcn"), _cfg())
    r0 = sim.run()
    r = _quiet_run(sim, fault_spec=FaultSpec(ecn_scale=0.0))
    assert r.finished
    assert r.completion_time != r0.completion_time


def test_link_degradation_and_flaps_delay_completion():
    topo, sched = _incast_case()
    sim = Simulator(topo, sched, get_policy("pfc"), _cfg())
    r0 = sim.run()
    r_deg = _quiet_run(sim, fault_spec=FaultSpec(
        degrade=0.5, degrade_t0=0.0, degrade_t1=1.0))
    r_flap = _quiet_run(sim, fault_spec=FaultSpec(
        flap_period=200e-6, flap_down=100e-6))
    assert r_deg.finished and r_flap.finished
    assert r_deg.completion_time > r0.completion_time
    assert r_flap.completion_time > r0.completion_time


def test_per_class_fault_leaves():
    """Per-link-class loss: the single-switch incast's last hop is a
    ``tor_down`` link, so loss scoped to that class must bite while loss
    scoped to an absent class (``spine_down``) must not."""
    topo, sched = _incast_case(2e6)
    sim = Simulator(topo, sched, get_policy("pfc"), _cfg())
    hit = FaultSpec().with_class(loss_rate={"tor_down": 1e-3})
    miss = FaultSpec().with_class(loss_rate={"spine_down": 1e-3})
    r_hit = _quiet_run(sim, fault_spec=hit)
    r_miss = _quiet_run(sim, fault_spec=miss)
    assert r_hit.lost.sum() > 0
    assert r_miss.lost.sum() == 0


@given(st.floats(min_value=0.0, max_value=5e-3),
       st.sampled_from(RECOVERY_MODES))
@settings(max_examples=8, deadline=None)
def test_loss_invariants_property(loss_rate, recovery):
    """Injected loss never drives the flow accounting out of bounds: lost
    bytes stay non-negative and finite, delivered stays finite and
    non-negative, and IRN (no duplicates) never delivers runaway extra
    bytes past the flow size."""
    topo, sched = _incast_case(1e6)
    cfg = _cfg(max_steps=1000, max_extends=2)
    sim = Simulator(topo, sched, get_policy("pfc"), cfg)
    r = _quiet_run(sim, fault_spec=FaultSpec.lossy_roce(
        loss_rate, recovery, pfc_on=True))
    if loss_rate == 0.0 and recovery == "irn":
        assert r.lost is None        # statically inert spec
        return
    assert np.all(np.isfinite(r.lost)) and np.all(r.lost >= 0)
    assert np.all(np.isfinite(r.delivered)) and np.all(r.delivered >= 0)
    if recovery == "irn":
        assert np.all(r.delivered <= sched.size * 1.1)


# ---------------------------------------------------------------------------
# run health: pause storms, pause-cycle deadlock, divergence isolation
# ---------------------------------------------------------------------------

def _ring_case(size=2e6):
    """3 switches in a directed ring with a genuine cyclic buffer
    dependency: flow i goes Gi -> G(i+2) the long way round, so every
    ring link is 2x oversubscribed and each one's congestion backs up
    into the previous — with small PFC thresholds the pause wait-for
    graph forms a 3-cycle (a textbook PFC deadlock; up-down CLOS routing
    is deadlock-free and can never build one)."""
    b = _Builder("ring3")
    for g in range(3):
        b.add_dev(f"gpu{g}", False)
    sw = [b.add_dev(f"sw{i}", True, SWITCH_BUF) for i in range(3)]
    up = [b.add_link(g, sw[g], NIC_BW, NIC_LAT, ecn=False) for g in range(3)]
    ring = [b.add_link(sw[i], sw[(i + 1) % 3], NIC_BW, NIC_LAT, ecn=True,
                       cls="tor_up") for i in range(3)]
    down = [b.add_link(sw[g], g, NIC_BW, NIC_LAT, ecn=True, cls="tor_down")
            for g in range(3)]
    topo = b.build(3, up, {"kind": "ring", "switches": sw})
    F = 3
    path = np.full((F, 4), -1, np.int32)
    for i in range(F):
        path[i] = [up[i], ring[i], ring[(i + 1) % 3], down[(i + 2) % 3]]
    sched = Schedule(path, np.full(F, 4, np.int32),
                     np.full(F, size, np.float32),
                     np.zeros(F, np.int32), np.full(F, -1, np.int32),
                     np.zeros(F, np.float32), n_groups=1, group_names=["g0"])
    return topo, sched


def test_pause_cycle_deadlock_is_detected():
    topo, sched = _ring_case()
    cfg = _cfg(max_steps=600, max_extends=0)
    sim = Simulator(topo, sched, get_policy("pfc"), cfg)
    r = _quiet_run(sim, fabric_params=FabricParams(xoff=30e3, xon=15e3))
    assert r.deadlocked
    assert r.deadlock_step >= 0
    assert r.storm_step >= 0        # every port pausing is also a storm
    assert not r.finished
    # huge thresholds: no pauses, no cycle — the ring just runs at half rate
    r_ok = _quiet_run(sim, fabric_params=FabricParams(xoff=32e6, xon=16e6))
    assert not r_ok.deadlocked
    assert r_ok.storm_step == -1
    assert r_ok.finished


def test_deadlocked_lane_reports_in_batch():
    """The same ring deadlock through the vmapped sweep path: the
    deadlocked lane is flagged per lane while a healthy lane completes."""
    topo, sched = _ring_case()
    cfg = _cfg(max_steps=600, max_extends=0)
    runner = SweepRunner(cfg)
    with pytest.warns(RuntimeWarning, match="lanes unhealthy"):
        batch = runner.run_batch(
            topo, sched, "pfc",
            stacked_fabric={"xoff": np.asarray([30e3, 32e6], np.float32),
                            "xon": np.asarray([15e3, 16e6], np.float32)})
    assert batch.deadlocked.tolist() == [True, False]
    assert batch.finished.tolist() == [False, True]
    assert batch.lane_status() == ["deadlocked", "ok"]


def test_diverged_lane_is_isolated_in_batch():
    """A NaN cc-param lane freezes and flags instead of poisoning the
    whole vmapped batch (the guard is always on, no fault spec needed)."""
    topo, sched = _incast_case(2e6)
    runner = SweepRunner(_cfg())
    stacked = {"g": np.asarray([np.nan, 1 / 256], np.float32)}
    with pytest.warns(RuntimeWarning, match="diverged"):
        batch = runner.run_batch(topo, sched, "dcqcn", stacked)
    assert batch.diverged.tolist() == [True, False]
    assert batch.lane_status() == ["diverged", "ok"]
    assert bool(batch.finished[1])
    assert np.all(np.isfinite(batch.t_finish[1]))
    # the diverged lane is never eligible as best()
    assert batch.best() == 1


def test_extend_exhausted_flag_and_warning():
    topo, sched = _incast_case()
    cfg = _cfg(max_steps=10, max_extends=0)
    sim = Simulator(topo, sched, get_policy("pfc"), cfg)
    with pytest.warns(RuntimeWarning, match="step budget exhausted"):
        r = sim.run()
    assert r.extend_exhausted
    assert not r.finished and not r.diverged
    # batched flavor: the per-lane flag plus the unhealthy-lane warning
    runner = SweepRunner(cfg)
    with pytest.warns(RuntimeWarning, match="lanes unhealthy"):
        batch = runner.grid(topo, sched, "dcqcn", {"g": [1 / 256, 1 / 128]})
    assert batch.extend_exhausted.tolist() == [True, True]
    assert batch.lane_status() == ["exhausted", "exhausted"]


# ---------------------------------------------------------------------------
# sweep integration: fault grids in one vmapped dispatch
# ---------------------------------------------------------------------------

def test_clos_allreduce_fault_sweep_one_dispatch():
    """The acceptance sweep: loss {0, 1e-5, 1e-3} x {IRN, go-back-N} x 3
    policies over a CLOS all-reduce as ONE vmapped dispatch with
    per-lane health."""
    from repro.core import sweep as sweep_mod
    spec = ScenarioSpec(
        fabric=FabricSpec(family="clos", n_racks=2, nodes_per_rack=1,
                          gpus_per_node=4),
        workload=CollectiveSpec("1d", 4e6),
        policy=("dcqcn", "hpcc", "timely"))
    runner = SweepRunner(_cfg(max_steps=2000, max_extends=2))
    n_exec_before = len(sweep_mod._BATCH_CACHE)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        batch = runner.grid_spec(
            spec, fault_grid={"loss_rate": [0.0, 1e-5, 1e-3],
                              "gbn": [0.0, 1.0]})
    # 3 loss x 2 recovery x 3 policies, one compiled batch executable
    assert batch.n == 18
    assert len(sweep_mod._BATCH_CACHE) == n_exec_before + 1
    assert len(batch.lane_status()) == 18
    assert {batch.policy_of(i) for i in range(18)} == \
        {"dcqcn", "hpcc", "timely"}
    loss = batch.fault["loss_rate"]
    gbn = batch.fault["gbn"]
    np.testing.assert_allclose(sorted(set(loss.tolist())),
                               [0.0, 1e-5, 1e-3], rtol=1e-6)
    # loss-free lanes are bitwise insensitive to the recovery model
    for i in range(18):
        if loss[i] != 0.0:
            continue
        for j in range(18):
            if (loss[j] == 0.0 and gbn[j] != gbn[i]
                    and batch.policy_of(j) == batch.policy_of(i)):
                np.testing.assert_array_equal(batch.t_finish[i],
                                              batch.t_finish[j])
    # per policy, completion is monotone non-decreasing in the loss rate
    # (among finished IRN lanes; an exhausted 1e-3 lane just drops out —
    # that is exactly what the per-lane health reporting is for)
    for polname in ("dcqcn", "hpcc", "timely"):
        lanes = [i for i in range(18)
                 if batch.policy_of(i) == polname and gbn[i] == 0.0
                 and batch.finished[i]]
        lanes.sort(key=lambda i: loss[i])
        cts = [batch.completion_time[i] for i in lanes]
        assert all(a <= b + 1e-9 for a, b in zip(cts, cts[1:]))


def test_scenario_spec_carries_fault_spec():
    topo, sched = _incast_case()
    spec_ok = ScenarioSpec(fabric=topo, workload=IncastSpec(7, 5e6),
                           policy="pfc")
    spec_bad = ScenarioSpec(fabric=topo, workload=IncastSpec(7, 5e6),
                            policy="pfc",
                            fault_spec=FaultSpec.lossy_roce(
                                1e-3, "gbn", pfc_on=True))
    runner = SweepRunner(_cfg())
    r_ok = runner.run_spec(spec_ok)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        r_bad = runner.run_spec(spec_bad)
    assert r_ok.finished and r_bad.finished
    assert r_bad.completion_time > r_ok.completion_time
