"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests and benches must see
the single real CPU device; only launch/dryrun.py forces 512 devices.

hypothesis is optional: property-based tests import the shim in
``tests/_hyp.py`` and auto-skip when it is missing.
"""
import jax
import pytest

try:
    from hypothesis import settings
except ImportError:
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=15, deadline=None)
    settings.load_profile("ci")


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
