"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests and benches must see
the single real CPU device; only launch/dryrun.py forces 512 devices."""
import jax
import pytest
from hypothesis import settings

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
