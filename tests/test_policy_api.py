"""Policy API v2: the batched policy axis, spec-driven grids, and
bounds-aware autotuning.

Acceptance gates (ISSUE 4):
* a policy x CC-param x fabric grid over >= 3 policies runs with ZERO
  recompiles after a same-shaped warmup (``sweep.compile_stats``);
* every lane of a stacked policy-axis dispatch matches the member
  policy's serial run at the golden tolerances.
"""
import numpy as np
import pytest

from repro.core.autotune import autotune
from repro.core.cc import (ALL_POLICIES, get_policy, stack_labels,
                           stack_policies)
from repro.core.collectives import incast
from repro.core.engine import EngineConfig, FabricParams
from repro.core.scenario import (CollectiveSpec, FabricSpec, IncastSpec,
                                 ScenarioSpec, scenario_matrix)
from repro.core.sweep import SweepRunner, compile_stats, grid_from_spec
from repro.core.topology import single_switch

CFG = EngineConfig(dt=1e-6, max_steps=1500, max_extends=2, queue_stride=0)


def _tiny_case():
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 3e6)
    return topo, sched


# ---------------------------------------------------------------------------
# stack_policies
# ---------------------------------------------------------------------------

def test_stack_policies_namespace_and_defaults():
    stacked = stack_policies(["dcqcn", "hpcc"])
    assert stacked.members == ("dcqcn", "hpcc")
    assert stacked.spec["_which"].integer
    assert "dcqcn.rai_frac" in stacked.spec
    assert "hpcc.eta" in stacked.spec
    assert stacked.params["_wire"] == pytest.approx(1.0)  # member 0 = dcqcn
    with pytest.raises(ValueError, match="at least two"):
        stack_policies(["dcqcn"])


def test_stack_labels_deduplicate():
    assert stack_labels(["dcqcn", "dcqcn", "hpcc"]) == \
        ["dcqcn0", "dcqcn1", "hpcc"]


def test_run_policy_axis_matches_serial_all_policies():
    """Every registered policy, one vmapped dispatch, vs its serial run —
    the PR-3 physics must reproduce lane by lane (incl. HPCC's wire
    factor and static_window's fanin-aware init)."""
    topo, sched = _tiny_case()
    runner = SweepRunner(CFG)
    batch = runner.run_policy_axis(topo, sched, ALL_POLICIES)
    assert batch.n == len(ALL_POLICIES)
    assert batch.policy_axis == ALL_POLICIES
    for i, pol in enumerate(ALL_POLICIES):
        serial = runner.run(topo, sched, pol)
        assert batch.policy_of(i) == pol
        assert bool(batch.finished[i]) == serial.finished
        np.testing.assert_allclose(batch.t_finish[i], serial.t_finish,
                                   rtol=1e-5)
        np.testing.assert_allclose(batch.pause_count[i], serial.pause_count,
                                   rtol=1e-3, atol=1.0)
        np.testing.assert_allclose(batch.delivered[i].sum(),
                                   serial.delivered.sum(), rtol=1e-4)


def test_run_policy_axis_cc_overrides_per_member():
    topo, sched = _tiny_case()
    runner = SweepRunner(CFG)
    over = [None, {"rai_frac": 0.2}]
    batch = runner.run_policy_axis(topo, sched, ["pfc", "dcqcn"],
                                   cc_overrides=over)
    serial = runner.run(topo, sched, "dcqcn",
                        cc_params=dict(get_policy("dcqcn").params,
                                       rai_frac=0.2))
    np.testing.assert_allclose(batch.t_finish[1], serial.t_finish, rtol=1e-5)
    with pytest.raises(ValueError, match="cc_overrides has"):
        runner.run_policy_axis(topo, sched, ["pfc", "dcqcn"],
                               cc_overrides=[{}])
    with pytest.raises(ValueError, match="unknown dcqcn"):
        runner.run_policy_axis(topo, sched, ["pfc", "dcqcn"],
                               cc_overrides=[None, {"bogus": 1.0}])


# ---------------------------------------------------------------------------
# acceptance gate: policy x param x fabric grid, zero recompiles
# ---------------------------------------------------------------------------

def test_policy_param_fabric_grid_zero_recompiles():
    """3 policies x 2 CC points x 2 fabric points = one 12-lane dispatch;
    after a same-shaped warmup the sweep adds ZERO compiled executables."""
    topo, sched = _tiny_case()
    runner = SweepRunner(CFG)
    axis = ["dcqcn", "dctcp", "hpcc"]

    def sweep(scale):
        return runner.grid(topo, sched,
                           param_grid={"dcqcn.rai_frac": [0.01 * scale,
                                                          0.05 * scale]},
                           fabric_grid={"xoff": [0.5e6 * scale, 1e6 * scale]},
                           policy_axis=axis)

    sweep(1.1)                       # warmup: same shapes, other values
    s0 = compile_stats()
    batch = sweep(1.0)
    assert compile_stats() == s0, "policy-axis grid recompiled after warmup"
    assert batch.n == 12
    assert batch.finished.all()
    assert {batch.policy_of(i) for i in range(batch.n)} == set(axis)
    # lanes must match serial per-member runs (spot-check every lane)
    which = batch.params["_which"].astype(int)
    for i in range(batch.n):
        pol = get_policy(axis[which[i]])
        cc = dict(pol.params)
        if axis[which[i]] == "dcqcn":
            cc["rai_frac"] = float(batch.params["dcqcn.rai_frac"][i])
        serial = runner.run(topo, sched, pol, cc_params=cc,
                            fabric_params=batch.fabric_set(i))
        np.testing.assert_allclose(batch.t_finish[i], serial.t_finish,
                                   rtol=1e-5)


def test_grid_policy_axis_validation():
    topo, sched = _tiny_case()
    runner = SweepRunner(CFG)
    with pytest.raises(ValueError, match="not both"):
        runner.grid(topo, sched, "dcqcn", {"rai_frac": [0.01]},
                    policy_axis=["dcqcn", "hpcc"])
    with pytest.raises(ValueError, match="member-namespaced"):
        runner.grid(topo, sched, param_grid={"rai_frac": [0.01, 0.05]},
                    policy_axis=["dcqcn", "hpcc"])
    with pytest.raises(ValueError, match="policy is required"):
        runner.grid(topo, sched, param_grid={"rai_frac": [0.01]})


def test_grid_spec_with_policy_tuple():
    spec = ScenarioSpec(
        fabric=FabricSpec(family="single", n_racks=1, nodes_per_rack=1,
                          gpus_per_node=8),
        workload=IncastSpec(n_senders=7, size_each=2e6),
        policy=("pfc", "dcqcn", "hpcc"))
    runner = SweepRunner(CFG)
    batch = runner.grid_spec(spec, fabric_grid={"xoff": [0.5e6, 2e6]})
    assert batch.n == 6
    assert batch.policy_axis == ("pfc", "dcqcn", "hpcc")
    assert batch.finished.all()


def test_run_spec_rejects_policy_axis_spec():
    spec = ScenarioSpec(
        fabric=FabricSpec(family="single", n_racks=1, nodes_per_rack=1,
                          gpus_per_node=4),
        workload=IncastSpec(n_senders=3, size_each=1e6),
        policy=("pfc", "dcqcn"))
    with pytest.raises(ValueError, match="policy axis"):
        SweepRunner(CFG).run_spec(spec)


def test_scenario_matrix_stacked():
    specs = scenario_matrix(
        FabricSpec(family="single", n_racks=1, nodes_per_rack=1,
                   gpus_per_node=8),
        [CollectiveSpec("1d", 2e6, n_chunks=2)],
        ["pfc", "dcqcn"], stacked=True)
    assert len(specs) == 1
    assert specs[0].policy == ("pfc", "dcqcn")
    assert specs[0].name.endswith("_stack")
    _, _, pol = specs[0].build()
    assert pol.members == ("pfc", "dcqcn")


# ---------------------------------------------------------------------------
# spec-driven grid axes
# ---------------------------------------------------------------------------

def test_grid_from_spec_scales_and_integers():
    axes = grid_from_spec("dcqcn", 3, ["rai_frac", "fast_rounds"])
    np.testing.assert_allclose(axes["rai_frac"],
                               np.geomspace(1e-4, 0.5, 3))     # log scale
    assert axes["fast_rounds"] == [0.0, 10.0, 20.0]            # int-rounded
    axes = grid_from_spec("hpcc", 3, ["eta"])
    np.testing.assert_allclose(axes["eta"], [0.5, 0.75, 1.0])  # linear
    # default key set: every bounded tunable
    assert set(grid_from_spec("dctcp")) == {"g", "mss", "ecn_thresh",
                                            "wmax_bdp"}
    with pytest.raises(ValueError, match="unknown"):
        grid_from_spec("dcqcn", 3, ["nope"])
    with pytest.raises(ValueError, match="consumed by init"):
        grid_from_spec("static_window", 3, ["margin"])


def test_grid_from_spec_feeds_grid():
    topo, sched = _tiny_case()
    runner = SweepRunner(CFG)
    batch = runner.grid(topo, sched, "dctcp",
                        grid_from_spec("dctcp", 2, ["g", "wmax_bdp"]))
    assert batch.n == 4
    assert batch.finished.all()


# ---------------------------------------------------------------------------
# autotune: integer rejection + bounds projection
# ---------------------------------------------------------------------------

def test_autotune_rejects_integer_params():
    topo, sched = _tiny_case()
    with pytest.raises(ValueError, match="integer-valued"):
        autotune(topo, sched, get_policy("dcqcn"), ["fast_rounds"],
                 steps=1, cfg=CFG)
    with pytest.raises(ValueError, match="integer-valued"):
        autotune(topo, sched, get_policy("hpcc"), ["max_stage"],
                 steps=1, cfg=CFG)


def test_autotune_projects_onto_bounds():
    """An absurd learning rate slams the tuned param into its declared
    bounds: every reported value stays in range and the projection is
    recorded in the history."""
    topo, sched = _tiny_case()
    cfg = EngineConfig(dt=2e-6, max_steps=400, max_extends=0, queue_stride=0)
    pol = get_policy("dcqcn")
    res = autotune(topo, sched, pol, ["rai_frac"], steps=3, lr=5e5,
                   cfg=cfg)
    s = pol.param_spec("rai_frac")
    for h in res.history:
        assert s.lo <= h["rai_frac"] <= s.hi
        assert isinstance(h["projected"], list)
    clamped = [h for h in res.history if "rai_frac" in h["projected"]]
    assert clamped, "no projection recorded despite the absurd step size"
    for h in clamped:                # a recorded projection sits at a bound
        assert h["rai_frac"] in (pytest.approx(s.lo), pytest.approx(s.hi))
    assert s.lo <= res.params["rai_frac"] <= s.hi


def test_autotune_linear_scale_param():
    """Linear-scale specs (TIMELY beta, HPCC eta) descend in value space
    and stay inside their declared [lo, hi]."""
    topo, sched = _tiny_case()
    cfg = EngineConfig(dt=2e-6, max_steps=300, max_extends=0, queue_stride=0)
    pol = get_policy("hpcc")
    res = autotune(topo, sched, pol, ["eta"], steps=2, lr=0.5, cfg=cfg,
                   population=3)
    s = pol.param_spec("eta")
    for h in res.history:
        assert s.lo <= h["eta"] <= s.hi
    assert res.tuned_cost <= res.baseline_cost + 1e-6


def test_autotune_fabric_keys_use_fabric_specs():
    from repro.core.engine import FABRIC_PARAM_SPECS
    topo, sched = _tiny_case()
    cfg = EngineConfig(dt=2e-6, max_steps=300, max_extends=0, queue_stride=0)
    res = autotune(topo, sched, get_policy("dcqcn"), [],
                   fabric_keys=["kmin"], steps=2, lr=50.0, cfg=cfg)
    s = FABRIC_PARAM_SPECS["kmin"]
    assert res.fabric is not None
    k = float(np.asarray(res.fabric.kmin))
    assert s.lo <= k <= s.hi
    for h in res.history:
        assert s.lo <= h["fabric.kmin"] <= s.hi


# ---------------------------------------------------------------------------
# serial simulation of a stacked policy (no vmap): _which selects members
# ---------------------------------------------------------------------------

def test_stacked_policy_serial_run_selects_member():
    topo, sched = _tiny_case()
    runner = SweepRunner(CFG)
    stacked = stack_policies(["pfc", "dcqcn"])
    r_pfc = runner.run(topo, sched, "pfc")
    r_dcqcn = runner.run(topo, sched, "dcqcn")
    params0 = dict(stacked.params, _which=0.0, _wire=1.0)
    params1 = dict(stacked.params, _which=1.0, _wire=1.0)
    s0 = runner.run(topo, sched, stacked, cc_params=params0)
    s1 = runner.run(topo, sched, stacked, cc_params=params1)
    np.testing.assert_allclose(s0.t_finish, r_pfc.t_finish, rtol=1e-5)
    np.testing.assert_allclose(s1.t_finish, r_dcqcn.t_finish, rtol=1e-5)
    assert s0.completion_time != s1.completion_time


def test_batch_pays_off_heuristics():
    """CPU defaults: same-policy param sweeps batch below the measured
    flow crossover (DEFAULT_CROSSOVERS); the stacked policy axis (switch
    runs every branch under vmap) batches only off-CPU (BENCH_engine.json
    policy_axis)."""
    import jax

    from repro.core import sweep as sweep_mod
    topo, sched = _tiny_case()
    runner = SweepRunner(CFG)
    sweep_mod.reset_calibration()
    try:
        if jax.default_backend() == "cpu":
            assert runner.batch_pays_off(sched)          # 7 flows
            thr = sweep_mod.DEFAULT_CROSSOVERS["cpu"]["sweep"]
            big = type("S", (), {"n_flows": int(thr) + 1})()
            assert not runner.batch_pays_off(big)
            assert not runner.policy_axis_pays_off()
        else:
            assert runner.batch_pays_off(sched)
            assert runner.policy_axis_pays_off()
    finally:
        sweep_mod.reset_calibration()


def test_readme_policy_table_in_sync():
    """The README policy table is generated from the registry — drift
    fails here (regenerate: PYTHONPATH=src python
    scripts/gen_policy_table.py)."""
    import os

    from repro.core.cc import policy_table_markdown
    path = os.path.join(os.path.dirname(__file__), "..", "README.md")
    with open(path) as f:
        text = f.read()
    start = "<!-- POLICY_TABLE_START"
    end = "<!-- POLICY_TABLE_END -->"
    assert start in text and end in text, "README lost the table markers"
    block = text.split(start, 1)[1].split(end, 1)[0]
    block = block.split("-->", 1)[1].strip()     # drop the marker tail
    assert block == policy_table_markdown(), (
        "README policy table is stale; run scripts/gen_policy_table.py")


def test_fabric_params_still_sweep_with_policy_axis():
    """Fabric leaves vary per lane alongside the policy selector."""
    topo, sched = _tiny_case()
    runner = SweepRunner(CFG)
    batch = runner.run_policy_axis(
        topo, sched, ["pfc", "dcqcn"],
        stacked_fabric={"xoff": np.asarray([0.2e6, 1e6], np.float32)})
    serial = runner.run(topo, sched, "pfc",
                        fabric_params=FabricParams(xoff=0.2e6))
    np.testing.assert_allclose(batch.pause_count[0], serial.pause_count,
                               rtol=1e-3, atol=1.0)
