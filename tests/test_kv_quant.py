"""int8 KV-cache decode (beyond-paper §Perf lever) correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import layers as L
from repro.models.model_api import Model


def test_quantize_roundtrip(key):
    x = jax.random.normal(key, (2, 16, 4, 32), jnp.float32)
    q, s = L.quantize_kv(x)
    deq = q.astype(jnp.float32) * s[..., None]
    rel = np.abs(np.asarray(deq - x)) / (np.abs(np.asarray(x)).max() + 1e-9)
    assert rel.max() < 0.02  # <2% of range per element


def test_decode_attention_quant_matches_fp(key):
    B, S, Hkv, G, D = 2, 64, 2, 2, 32
    q = jax.random.normal(key, (B, 1, Hkv * G, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D))
    ref = L.decode_attention(q, k, v, length=jnp.asarray(S - 5))
    kq, ks = L.quantize_kv(k)
    vq, vs = L.quantize_kv(v)
    out = L.decode_attention_quant(q, kq, vq, ks, vs, length=jnp.asarray(S - 5))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=0.05, rtol=0.05)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-9b"])
def test_model_decode_with_quant_cache(arch, key):
    cfg = dataclasses.replace(smoke_config(arch), kv_quant_int8=True)
    m = Model(cfg)
    params = m.init(key)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    _, cache = jax.jit(lambda p, b: m.prefill(p, b, max_len=S + 8))(
        params, {"tokens": toks[:, :S]})
    lg_q, _ = jax.jit(m.decode_step)(params, cache, toks[:, S:S + 1])

    m_fp = Model(smoke_config(arch))
    lg_fp, _ = jax.jit(lambda p, b: m_fp.prefill(p, b, max_len=S + 9))(
        params, {"tokens": toks[:, :S + 1]})
    a = np.asarray(lg_q, np.float32)
    b = np.asarray(lg_fp, np.float32)
    assert np.mean(np.abs(a - b)) < 0.08
    assert (a.argmax(-1) == b.argmax(-1)).mean() >= 0.5
