"""Unit behaviour of each CC policy's defining mechanism (paper §II-D),
plus Policy-API-v2 invariants: ParamSpec tables, typed Signals/FlowCtx,
and randomized property tests over the whole registry."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.cc import (ALL_POLICIES, FlowCtx, ParamSpec,
                           Signals, get_policy, make_dcqcn, make_dctcp,
                           make_hpcc, make_static_window, make_timely,
                           policy_table_markdown, stack_policies)

LINE = 25e9
F = 4


def _sig(t=0.0, ecn=0.0, rtt=2e-6, util=0.1, F=F):
    return Signals(ecn=jnp.full((F,), ecn, jnp.float32),
                   rtt=jnp.full((F,), rtt, jnp.float32),
                   util=jnp.full((F,), util, jnp.float32),
                   t=jnp.asarray(t, jnp.float32), dt=jnp.float32(1e-6),
                   line=jnp.full((F,), LINE, jnp.float32),
                   base_rtt=jnp.full((F,), 2e-6, jnp.float32))


def _ctx(F=F):
    line = jnp.full((F,), LINE, jnp.float32)
    return FlowCtx.make(line, line * 2e-6)


def _init(pol, F=F):
    return pol.init(_ctx(F))


def test_pfc_only_always_line_rate():
    pol = get_policy("pfc")
    st = _init(pol)
    st, rate, win = pol.update(pol.params, st, _sig(ecn=1.0, rtt=1.0))
    assert np.all(np.asarray(rate) == LINE)
    assert np.all(np.asarray(win) > 1e15)


def test_dcqcn_cuts_on_cnp_and_recovers():
    pol = make_dcqcn()
    st = _init(pol)
    st, rate, _ = pol.update(pol.params, st, _sig(t=1e-4, ecn=0.5))
    cut_rate = np.asarray(rate)
    assert np.all(cut_rate < LINE)  # multiplicative decrease
    # no marks for a long time -> recovery toward line rate
    for i in range(200):
        st, rate, _ = pol.update(pol.params, st, _sig(t=1e-4 + (i + 1) * 55e-6))
    assert np.all(np.asarray(rate) > cut_rate * 1.5)


def test_dcqcn_rate_dependent_cnp():
    """A collapsed-rate flow sends few packets -> few CNPs -> smaller cut."""
    pol = make_dcqcn()
    st = _init(pol)
    st["rc"] = jnp.asarray([25e9, 25e6, 25e9, 25e6], jnp.float32)
    st2, rate, _ = pol.update(pol.params, st, _sig(t=1e-4, ecn=0.02))
    r = np.asarray(rate)
    assert r[0] / 25e9 < r[1] / 25e6  # high-rate flow cut proportionally more


def test_dctcp_window_proportional_to_marking():
    pol = make_dctcp()
    st = _init(pol)
    w0 = np.asarray(st["w"]).copy()
    # marked RTT -> shrink ~alpha/2
    st, _, w = pol.update(pol.params, st, _sig(t=5e-6, ecn=1.0))
    assert np.all(np.asarray(w) < w0)
    # unmarked RTTs -> additive growth
    st, _, w1 = pol.update(pol.params, st, _sig(t=15e-6, ecn=0.0))
    st, _, w2 = pol.update(pol.params, st, _sig(t=25e-6, ecn=0.0))
    assert np.all(np.asarray(w2) >= np.asarray(w1))


def test_timely_gradient_rule():
    pol = make_timely()
    st = _init(pol)
    # rtt far above thigh -> multiplicative decrease
    st, rate, _ = pol.update(pol.params, st, _sig(t=1e-4, rtt=5e-3))
    assert np.all(np.asarray(rate) < LINE)
    # rtt below tlow -> additive increase
    st2 = _init(pol)
    st2["rate"] = jnp.full((F,), LINE / 10, jnp.float32)
    st2, rate2, _ = pol.update(pol.params, st2, _sig(t=1e-4, rtt=1e-6))
    assert np.all(np.asarray(rate2) > LINE / 10)


def test_hpcc_targets_eta_utilization():
    pol = make_hpcc()
    st = _init(pol)
    w0 = np.asarray(st["w"]).copy()
    # util far above eta -> window shrinks
    st, _, w = pol.update(pol.params, st, _sig(t=5e-6, util=2.0))
    assert np.all(np.asarray(w) < w0)
    # util below eta -> grows (additive probe)
    st2 = _init(pol)
    st2, _, w2 = pol.update(pol.params, st2, _sig(t=5e-6, util=0.2))
    assert np.all(np.asarray(w2) >= w0)


def test_hpcc_wire_overhead_is_modeled():
    assert get_policy("hpcc").wire_factor > 1.04
    assert get_policy("hpcc_pint").wire_factor < 1.01


def test_static_window_is_static_and_bdp_sized():
    pol = make_static_window(margin=1.2, headroom=0.5e6)
    st = _init(pol)
    w0 = np.asarray(st["w"]).copy()
    np.testing.assert_allclose(w0, 1.2 * LINE * 2e-6 + 0.5e6, rtol=1e-5)
    st, rate, w = pol.update(pol.params, st, _sig(ecn=1.0, rtt=1.0, util=5.0))
    np.testing.assert_allclose(np.asarray(w), w0, rtol=1e-6)  # no feedback


def test_static_window_fanin_shares_port_budget():
    pol = make_static_window(margin=2.0, headroom=1e6)
    line = jnp.full((F,), LINE, jnp.float32)
    fanin = jnp.asarray([1.0, 7.0, 56.0, 1.0], jnp.float32)
    st = pol.init(FlowCtx.make(line, line * 2e-6, fanin=fanin))
    w = np.asarray(st["w"])
    # aggregate in-flight at a port stays ~bounded regardless of fan-in
    np.testing.assert_allclose(w[1] * 7, w[0], rtol=1e-5)
    assert w[2] * 56 <= w[0] * 1.001


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_all_policies_rates_bounded(name):
    pol = get_policy(name)
    st = _init(pol)
    for i in range(50):
        st, rate, win = pol.update(pol.params, st,
                                   _sig(t=i * 1e-5, ecn=(i % 3 == 0) * 0.5,
                                        rtt=2e-6 + (i % 5) * 1e-4, util=0.2 + i % 2))
        r = np.asarray(rate)
        assert np.all(r <= LINE * 1.0001), name
        assert np.all(r > 0), name
        assert np.all(np.isfinite(np.asarray(win))), name


# ---------------------------------------------------------------------------
# ParamSpec tables
# ---------------------------------------------------------------------------

def test_param_specs_declare_defaults_and_bounds():
    for name in ALL_POLICIES:
        pol = get_policy(name)
        for k, s in pol.spec.items():
            assert isinstance(s, ParamSpec), (name, k)
            assert s.scale in ("linear", "log")
            if s.bounded:
                assert s.lo <= s.default <= s.hi, (name, k)
        # the params dict is derived from the spec
        assert pol.params == {k: s.default for k, s in pol.spec.items()}


def test_factory_overrides_land_in_spec_defaults():
    pol = make_dcqcn(rai_frac=0.07, fast_rounds=3)
    assert pol.spec["rai_frac"].default == pytest.approx(0.07)
    assert pol.spec["fast_rounds"].default == 3
    # metadata (bounds/scale/integer) is static per policy
    assert pol.spec["rai_frac"].scale == "log"
    assert pol.spec["fast_rounds"].integer


def test_integer_params_declared():
    assert get_policy("dcqcn").spec["fast_rounds"].integer
    assert get_policy("dcqcn").spec["hai_after"].integer
    assert get_policy("hpcc").spec["max_stage"].integer
    assert get_policy("timely").spec["hai_thresh"].integer


def test_init_baked_params_rejected_by_check_tunable():
    pol = get_policy("static_window")
    assert set(pol.init_params) == {"margin", "headroom", "min_w"}
    with pytest.raises(ValueError, match="consumed by init"):
        pol.check_tunable(["margin"])
    with pytest.raises(ValueError, match="unknown"):
        pol.check_tunable(["nope"])
    with pytest.raises(KeyError, match="unknown static_window param"):
        pol.param_spec("nope")


def test_param_spec_validation():
    with pytest.raises(ValueError, match="positive lo"):
        ParamSpec(1.0, lo=0.0, hi=2.0, scale="log")
    with pytest.raises(ValueError, match="scale"):
        ParamSpec(1.0, scale="cubic")
    s = ParamSpec(1.0, lo=0.5, hi=2.0)
    assert s.clip(10.0) == 2.0 and s.clip(0.1) == 0.5


def test_policy_table_markdown_lists_registry():
    table = policy_table_markdown()
    for name in ALL_POLICIES:
        assert f"| `{name}` |" in table
    assert "`rai_frac`" in table and "init-baked" in table


# ---------------------------------------------------------------------------
# randomized policy invariants (satellite: scan/vmap-safe state, bounded
# outputs).  The hypothesis variant auto-skips when hypothesis is missing;
# the numpy-seeded variant always runs.
# ---------------------------------------------------------------------------

def _rand_sig(rng, F, t):
    return Signals(
        ecn=jnp.asarray(rng.uniform(0, 1, F), jnp.float32),
        rtt=jnp.asarray(rng.uniform(1e-7, 1e-2, F), jnp.float32),
        util=jnp.asarray(rng.uniform(1e-3, 10.0, F), jnp.float32),
        t=jnp.asarray(t, jnp.float32), dt=jnp.float32(1e-6),
        line=jnp.full((F,), LINE, jnp.float32),
        base_rtt=jnp.full((F,), 2e-6, jnp.float32))


def _tree_sig(state):
    return jax.tree_util.tree_structure(state), \
        [(x.shape, x.dtype) for x in jax.tree_util.tree_leaves(state)]


def _check_policy_invariants(pol, seed, n_steps=25):
    rng = np.random.default_rng(seed)
    st = _init(pol)
    sig0 = _tree_sig(st)
    for i in range(n_steps):
        st, rate, win = pol.update(pol.params, st,
                                   _rand_sig(rng, F, t=(i + 1) * 13e-6))
        r, w = np.asarray(rate), np.asarray(win)
        assert r.shape == (F,) and w.shape == (F,), pol.name
        assert np.all(r > 0), pol.name
        assert np.all(r <= LINE * 1.0001), pol.name
        assert np.all(w > 0), pol.name
        # scan/vmap safety: stable pytree structure, shapes and dtypes
        assert _tree_sig(st) == sig0, pol.name


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_policy_invariants_randomized(name):
    _check_policy_invariants(get_policy(name), seed=42)


def test_stacked_policy_invariants_randomized():
    _check_policy_invariants(stack_policies(["dcqcn", "hpcc", "timely"]),
                             seed=7)


@given(st.sampled_from(ALL_POLICIES), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_policy_invariants_property(name, seed):
    _check_policy_invariants(get_policy(name), seed=seed, n_steps=8)


# ---------------------------------------------------------------------------
# the loss signal (fault-injection layer): loss-aware policies react,
# everyone is a bitwise no-op at loss 0
# ---------------------------------------------------------------------------

def _sig_loss(loss, **kw):
    return _sig(**kw).replace(loss=jnp.full((F,), loss, jnp.float32))


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_loss_reaction_matches_loss_aware_flag(name):
    """loss_aware policies slow down under a loss signal (NACK-driven
    cut); the rest ignore it entirely."""
    pol = get_policy(name)
    kw = dict(t=1e-4, ecn=0.0, rtt=2e-6, util=0.2)
    st0 = _init(pol)
    _, r0, w0 = pol.update(pol.params, st0, _sig(**kw))
    _, rl, wl = pol.update(pol.params, _init(pol), _sig_loss(0.3, **kw))
    r0, w0 = np.asarray(r0), np.asarray(w0)
    rl, wl = np.asarray(rl), np.asarray(wl)
    if pol.loss_aware:
        assert np.all(rl <= r0) and np.all(wl <= w0), name
        assert (rl < r0).any() or (wl < w0).any(), name
    else:
        assert np.array_equal(rl, r0) and np.array_equal(wl, w0), name


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_explicit_zero_loss_is_bitwise_noop(name):
    """A Signals carrying an explicit loss=0 array must produce bitwise
    the same update as the default-constructed (scalar 0) signal — the
    contract that keeps lossless goldens exact."""
    pol = get_policy(name)
    kw = dict(t=1e-4, ecn=0.4, rtt=1e-4, util=1.5)
    st1, r1, w1 = pol.update(pol.params, _init(pol), _sig(**kw))
    st2, r2, w2 = pol.update(pol.params, _init(pol), _sig_loss(0.0, **kw))
    assert np.array_equal(np.asarray(r1), np.asarray(r2)), name
    assert np.array_equal(np.asarray(w1), np.asarray(w2)), name
    for a, b in zip(jax.tree_util.tree_leaves(st1),
                    jax.tree_util.tree_leaves(st2)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), name


def test_loss_aware_flag_covers_the_reactive_policies():
    aware = {n for n in ALL_POLICIES if get_policy(n).loss_aware}
    assert {"dcqcn", "dctcp", "timely", "hpcc", "hpcc_pint"} <= aware
    assert "pfc" not in aware


# ---------------------------------------------------------------------------
# typed structs
# ---------------------------------------------------------------------------

def test_signals_is_a_pytree():
    sig = _sig(t=1e-4, ecn=0.3)
    leaves = jax.tree_util.tree_leaves(sig)
    assert len(leaves) == 8  # incl. the loss signal (defaults to 0)
    doubled = jax.tree_util.tree_map(lambda x: x * 2, sig)
    np.testing.assert_allclose(np.asarray(doubled.ecn),
                               2 * np.asarray(sig.ecn))
    rep = sig.replace(base_rtt=sig.base_rtt * 2)
    np.testing.assert_allclose(np.asarray(rep.base_rtt),
                               2 * np.asarray(sig.base_rtt))
    with pytest.raises(dataclasses.FrozenInstanceError):
        sig.ecn = sig.rtt


def test_flowctx_make_defaults_fanin():
    ctx = _ctx()
    assert ctx.n_flows == F
    np.testing.assert_array_equal(np.asarray(ctx.fanin), np.ones(F))
    # pytree with static n_flows
    mapped = jax.tree_util.tree_map(lambda x: x, ctx)
    assert mapped.n_flows == F
