"""Unit behaviour of each CC policy's defining mechanism (paper §II-D)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cc import (ALL_POLICIES, get_policy, make_dcqcn, make_dctcp,
                           make_hpcc, make_static_window, make_timely)

LINE = 25e9
F = 4


def _sig(t=0.0, ecn=0.0, rtt=2e-6, util=0.1):
    return {"ecn": jnp.full((F,), ecn, jnp.float32),
            "rtt": jnp.full((F,), rtt, jnp.float32),
            "util": jnp.full((F,), util, jnp.float32),
            "t": jnp.asarray(t, jnp.float32), "dt": 1e-6,
            "line": jnp.full((F,), LINE, jnp.float32),
            "base_rtt": jnp.full((F,), 2e-6, jnp.float32)}


def _init(pol):
    line = jnp.full((F,), LINE, jnp.float32)
    return pol.init(F, line, line * 2e-6)


def test_pfc_only_always_line_rate():
    pol = get_policy("pfc")
    st = _init(pol)
    st, rate, win = pol.update(pol.params, st, _sig(ecn=1.0, rtt=1.0))
    assert np.all(np.asarray(rate) == LINE)
    assert np.all(np.asarray(win) > 1e15)


def test_dcqcn_cuts_on_cnp_and_recovers():
    pol = make_dcqcn()
    st = _init(pol)
    st, rate, _ = pol.update(pol.params, st, _sig(t=1e-4, ecn=0.5))
    cut_rate = np.asarray(rate)
    assert np.all(cut_rate < LINE)  # multiplicative decrease
    # no marks for a long time -> recovery toward line rate
    r = cut_rate
    for i in range(200):
        st, rate, _ = pol.update(pol.params, st, _sig(t=1e-4 + (i + 1) * 55e-6))
    assert np.all(np.asarray(rate) > cut_rate * 1.5)


def test_dcqcn_rate_dependent_cnp():
    """A collapsed-rate flow sends few packets -> few CNPs -> smaller cut."""
    pol = make_dcqcn()
    st = _init(pol)
    st["rc"] = jnp.asarray([25e9, 25e6, 25e9, 25e6], jnp.float32)
    st2, rate, _ = pol.update(pol.params, st, _sig(t=1e-4, ecn=0.02))
    r = np.asarray(rate)
    assert r[0] / 25e9 < r[1] / 25e6  # high-rate flow cut proportionally more


def test_dctcp_window_proportional_to_marking():
    pol = make_dctcp()
    st = _init(pol)
    w0 = np.asarray(st["w"]).copy()
    # marked RTT -> shrink ~alpha/2
    st, _, w = pol.update(pol.params, st, _sig(t=5e-6, ecn=1.0))
    assert np.all(np.asarray(w) < w0)
    # unmarked RTTs -> additive growth
    st, _, w1 = pol.update(pol.params, st, _sig(t=15e-6, ecn=0.0))
    st, _, w2 = pol.update(pol.params, st, _sig(t=25e-6, ecn=0.0))
    assert np.all(np.asarray(w2) >= np.asarray(w1))


def test_timely_gradient_rule():
    pol = make_timely()
    st = _init(pol)
    # rtt far above thigh -> multiplicative decrease
    st, rate, _ = pol.update(pol.params, st, _sig(t=1e-4, rtt=5e-3))
    assert np.all(np.asarray(rate) < LINE)
    # rtt below tlow -> additive increase
    st2 = _init(pol)
    st2["rate"] = jnp.full((F,), LINE / 10, jnp.float32)
    st2, rate2, _ = pol.update(pol.params, st2, _sig(t=1e-4, rtt=1e-6))
    assert np.all(np.asarray(rate2) > LINE / 10)


def test_hpcc_targets_eta_utilization():
    pol = make_hpcc()
    st = _init(pol)
    w0 = np.asarray(st["w"]).copy()
    # util far above eta -> window shrinks
    st, _, w = pol.update(pol.params, st, _sig(t=5e-6, util=2.0))
    assert np.all(np.asarray(w) < w0)
    # util below eta -> grows (additive probe)
    st2 = _init(pol)
    st2, _, w2 = pol.update(pol.params, st2, _sig(t=5e-6, util=0.2))
    assert np.all(np.asarray(w2) >= w0)


def test_hpcc_wire_overhead_is_modeled():
    assert get_policy("hpcc").wire_factor > 1.04
    assert get_policy("hpcc_pint").wire_factor < 1.01


def test_static_window_is_static_and_bdp_sized():
    pol = make_static_window(margin=1.2, headroom=0.5e6)
    st = _init(pol)
    w0 = np.asarray(st["w"]).copy()
    np.testing.assert_allclose(w0, 1.2 * LINE * 2e-6 + 0.5e6, rtol=1e-5)
    st, rate, w = pol.update(pol.params, st, _sig(ecn=1.0, rtt=1.0, util=5.0))
    np.testing.assert_allclose(np.asarray(w), w0, rtol=1e-6)  # no feedback


def test_static_window_fanin_shares_port_budget():
    pol = make_static_window(margin=2.0, headroom=1e6)
    line = jnp.full((F,), LINE, jnp.float32)
    fanin = jnp.asarray([1.0, 7.0, 56.0, 1.0], jnp.float32)
    st = pol.init(F, line, line * 2e-6, fanin=fanin)
    w = np.asarray(st["w"])
    # aggregate in-flight at a port stays ~bounded regardless of fan-in
    np.testing.assert_allclose(w[1] * 7, w[0], rtol=1e-5)
    assert w[2] * 56 <= w[0] * 1.001


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_all_policies_rates_bounded(name):
    pol = get_policy(name)
    st = _init(pol)
    for i in range(50):
        st, rate, win = pol.update(pol.params, st,
                                   _sig(t=i * 1e-5, ecn=(i % 3 == 0) * 0.5,
                                        rtt=2e-6 + (i % 5) * 1e-4, util=0.2 + i % 2))
        r = np.asarray(rate)
        assert np.all(r <= LINE * 1.0001), name
        assert np.all(r > 0), name
        assert np.all(np.isfinite(np.asarray(win))), name
