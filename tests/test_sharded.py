"""Sharded sweep execution: shard_map grid scale-out vs the vmap path.

The multi-device equivalence tests need >1 JAX device and auto-skip on
the plain single-CPU tier-1 run; CI runs them (marker ``sharded``) under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.  The chunking,
padding-arithmetic, mesh-resolution and calibration-persistence tests are
single-device-safe and always run.
"""
import dataclasses
import json
import warnings

import jax
import numpy as np
import pytest

from repro.common.sharding import GRID_AXIS, grid_mesh, resolve_grid_mesh
from repro.core import sweep as sweep_mod
from repro.core.collectives import allreduce_1d, incast
from repro.core.engine import EngineConfig
from repro.core.faults import FaultSpec
from repro.core.scenario import CollectiveSpec, scenario_matrix
from repro.core.sweep import BackendCalibration, SweepRunner
from repro.core.topology import single_switch

pytestmark = pytest.mark.sharded

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >1 JAX device "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

CFG = EngineConfig(dt=2e-6, max_steps=600, max_extends=1, queue_stride=0)


def scenario(n=4, mb=4e6):
    topo = single_switch(n)
    return topo, allreduce_1d(topo, list(range(n)), mb)


# -- mesh resolution / chunk arithmetic (single-device-safe) ----------------

def test_resolve_grid_mesh_modes():
    assert resolve_grid_mesh(None) is None
    if N_DEV < 2:
        assert resolve_grid_mesh("auto") is None   # 1 device -> vmap path
    else:
        m = resolve_grid_mesh("auto")
        assert m.axis_names == (GRID_AXIS,)
        assert resolve_grid_mesh(m) is m
        assert resolve_grid_mesh(2).devices.size == 2
    with pytest.raises(TypeError):
        resolve_grid_mesh(3.5)
    with pytest.raises(ValueError):
        grid_mesh(N_DEV + 1)


def test_runner_defaults_unchanged():
    """mesh=None is the historical single-dispatch path."""
    r = SweepRunner(CFG)
    assert r.mesh is None
    assert r.n_mesh_devices == 1
    assert not r.sharded_pays_off()
    # one chunk covers any grid up to the auto limit
    assert r._chunk_size(7) == 7
    assert r._chunk_size(SweepRunner.AUTO_CHUNK_PER_DEVICE) == \
        SweepRunner.AUTO_CHUNK_PER_DEVICE
    assert r._chunk_size(SweepRunner.AUTO_CHUNK_PER_DEVICE + 1) == \
        SweepRunner.AUTO_CHUNK_PER_DEVICE


def test_chunk_size_is_mesh_multiple():
    r = SweepRunner(CFG, chunk_lanes=10)
    assert r._chunk_size(100) == 10
    assert r._chunk_size(4) == 4          # padded up only to B
    if N_DEV > 1:
        rs = SweepRunner(CFG, mesh="auto", chunk_lanes=10)
        c = rs._chunk_size(100)
        assert c % rs.n_mesh_devices == 0 and c >= 10
        assert rs._chunk_size(3) == rs.n_mesh_devices   # pad 3 -> mesh


def test_unsharded_chunked_streaming_matches_single_dispatch():
    """Chunked streaming (mesh=None) returns exactly B lanes in input
    order, trailing-pad dropped, allclose with the one-dispatch path."""
    topo, sched = scenario()
    B = 11                                 # 3 chunks of 4, last padded
    scale = np.linspace(0.5, 2.0, B).astype(np.float32)
    stacked = {"rai_frac": 0.03 * scale}
    one = SweepRunner(CFG).run_batch(topo, sched, "dcqcn", stacked)
    chunked = SweepRunner(CFG, chunk_lanes=4).run_batch(
        topo, sched, "dcqcn", stacked)
    assert chunked.n == B
    np.testing.assert_allclose(chunked.completion_time,
                               one.completion_time, rtol=1e-5)
    np.testing.assert_allclose(chunked.t_finish, one.t_finish, rtol=1e-5)
    assert chunked.lane_status() == one.lane_status()
    # per-lane params survive the chunk round-trip in order
    np.testing.assert_allclose(chunked.params["rai_frac"],
                               stacked["rai_frac"])


def test_lane_state_bytes_positive_and_faulty_larger():
    topo, sched = scenario()
    r = SweepRunner(CFG)
    base = r.lane_state_bytes(topo, sched, "dcqcn")
    assert base > 0
    assert r.lane_state_bytes(topo, sched, "dcqcn", faulty=True) > base


# -- calibration persistence (single-device-safe) ---------------------------

def test_calibration_save_load_roundtrip(tmp_path):
    cal = BackendCalibration(
        backend=jax.default_backend(), source="measured",
        crossover={"sweep": 123.0, "policy_axis": 0.0,
                   "sharded": float("inf")},
        probes=(("sweep", 90, 0.5, 0.2),))
    path = str(tmp_path / "cal.json")
    assert sweep_mod.save_calibration(cal, path) == path
    got = sweep_mod.load_calibration(path=path)
    assert got is not None
    assert got.crossover == cal.crossover
    assert got.probes == cal.probes
    assert got.source == "measured"


def test_calibration_load_rejects_mismatch(tmp_path):
    cal = BackendCalibration(backend=jax.default_backend(),
                             source="measured", crossover={"sweep": 1.0})
    path = str(tmp_path / "cal.json")
    sweep_mod.save_calibration(cal, path)
    rec = json.load(open(path))
    # wrong backend
    rec2 = dict(rec, backend="not-a-backend")
    json.dump(rec2, open(path, "w"))
    assert sweep_mod.load_calibration(path=path) is None
    # wrong jax version
    rec2 = dict(rec, jax="0.0.0")
    json.dump(rec2, open(path, "w"))
    assert sweep_mod.load_calibration(path=path) is None
    # stale
    rec2 = dict(rec, saved_at=0.0)
    json.dump(rec2, open(path, "w"))
    assert sweep_mod.load_calibration(path=path, max_age_days=1.0) is None
    json.dump(rec, open(path, "w"))
    assert sweep_mod.load_calibration(path=path) is not None


def test_get_calibration_warm_starts_from_disk(tmp_path, monkeypatch):
    """A fresh process (simulated: cleared in-memory table + _NO_DISK)
    picks up the persisted measurement; reset_calibration pins back to
    the defaults without reconsulting the file."""
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    backend = jax.default_backend()
    cal = BackendCalibration(backend=backend, source="measured",
                             crossover={"sweep": 777.0})
    sweep_mod.save_calibration(cal)
    saved_mem = dict(sweep_mod._CALIBRATION)
    saved_nodisk = set(sweep_mod._NO_DISK)
    try:
        sweep_mod._CALIBRATION.clear()
        sweep_mod._NO_DISK.clear()
        got = sweep_mod.get_calibration()
        assert got.source == "measured"
        assert got.crossover["sweep"] == 777.0
        sweep_mod.reset_calibration()
        assert sweep_mod.get_calibration().source == "default"
    finally:
        sweep_mod._CALIBRATION.clear()
        sweep_mod._CALIBRATION.update(saved_mem)
        sweep_mod._NO_DISK.clear()
        sweep_mod._NO_DISK.update(saved_nodisk)


def test_get_calibration_env_gate(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_CALIBRATION_CACHE", "0")
    backend = jax.default_backend()
    sweep_mod.save_calibration(BackendCalibration(
        backend=backend, source="measured", crossover={"sweep": 777.0}))
    saved_mem = dict(sweep_mod._CALIBRATION)
    saved_nodisk = set(sweep_mod._NO_DISK)
    try:
        sweep_mod._CALIBRATION.clear()
        sweep_mod._NO_DISK.clear()
        assert sweep_mod.get_calibration().source == "default"
    finally:
        sweep_mod._CALIBRATION.clear()
        sweep_mod._CALIBRATION.update(saved_mem)
        sweep_mod._NO_DISK.clear()
        sweep_mod._NO_DISK.update(saved_nodisk)


# -- sharded-vs-vmap equivalence (multi-device) -----------------------------

@multi_device
def test_sharded_grid_matches_vmap():
    """Divisible and non-divisible grids through shard_map match the
    single-device vmap at rtol 1e-5, padded remainder lanes masked out."""
    topo, sched = scenario()
    vm = SweepRunner(CFG)
    sh = SweepRunner(CFG, mesh="auto")
    assert sh.n_mesh_devices == N_DEV
    for B in (N_DEV, 2 * N_DEV, N_DEV + 3, 2 * N_DEV - 1):
        scale = np.linspace(0.5, 2.0, B).astype(np.float32)
        grid = {"rai_frac": [0.01, 0.05], "timer": [40e-6, 70e-6]}
        a = vm.run_batch(topo, sched, "dcqcn", {"rai_frac": 0.03 * scale})
        b = sh.run_batch(topo, sched, "dcqcn", {"rai_frac": 0.03 * scale})
        assert b.n == B
        np.testing.assert_allclose(b.completion_time, a.completion_time,
                                   rtol=1e-5)
        np.testing.assert_allclose(b.t_finish, a.t_finish, rtol=1e-5)
        assert a.lane_status() == b.lane_status()
    ga = vm.grid(topo, sched, "dcqcn", grid)
    gb = sh.grid(topo, sched, "dcqcn", grid)
    np.testing.assert_allclose(gb.completion_time, ga.completion_time,
                               rtol=1e-5)


@multi_device
def test_sharded_chunked_streaming_matches():
    """Streamed chunks (3 chunks, trailing pad) through the mesh match
    the one-dispatch vmap; round-robin permutation restores lane order."""
    topo, sched = scenario()
    B = 3 * N_DEV - 2
    scale = np.linspace(0.5, 2.0, B).astype(np.float32)
    stacked = {"rai_frac": 0.03 * scale}
    a = SweepRunner(CFG).run_batch(topo, sched, "dcqcn", stacked)
    b = SweepRunner(CFG, mesh="auto", chunk_lanes=N_DEV).run_batch(
        topo, sched, "dcqcn", stacked)
    assert b.n == B
    np.testing.assert_allclose(b.completion_time, a.completion_time,
                               rtol=1e-5)
    np.testing.assert_allclose(b.params["rai_frac"], stacked["rai_frac"])


@multi_device
def test_sharded_policy_axis_matches():
    topo, sched = scenario()
    pols = ["dcqcn", "timely", "hpcc", "dctcp", "pfc"]
    a = SweepRunner(CFG).run_policy_axis(topo, sched, pols)
    b = SweepRunner(CFG, mesh="auto").run_policy_axis(topo, sched, pols)
    np.testing.assert_allclose(b.completion_time, a.completion_time,
                               rtol=1e-5)
    assert a.lane_status() == b.lane_status()
    assert [b.policy_of(i) for i in range(b.n)] == pols


@multi_device
@pytest.mark.fault
def test_sharded_fault_grid_lane_isolation():
    """A fault grid with unhealthy lanes shards like it vmaps: per-lane
    status (incl. isolation of non-finishing lanes) is identical and
    healthy-lane results are allclose."""
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 5e6)
    cfg = EngineConfig(dt=1e-6, max_steps=400, max_extends=0,
                       queue_stride=0)
    fault_grid = {"loss_rate": [0.0, 1e-4, 3e-3], "gbn": [0.0, 1.0]}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        a = SweepRunner(cfg).grid(topo, sched, "dcqcn",
                                  {"rai_frac": [0.03]},
                                  fault_grid=fault_grid,
                                  fault_spec=FaultSpec(pfc_on=0.0))
        b = SweepRunner(cfg, mesh="auto").grid(
            topo, sched, "dcqcn", {"rai_frac": [0.03]},
            fault_grid=fault_grid, fault_spec=FaultSpec(pfc_on=0.0))
    assert a.lane_status() == b.lane_status()
    ok = np.asarray([s == "ok" for s in a.lane_status()])
    np.testing.assert_allclose(b.completion_time[ok],
                               a.completion_time[ok], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(b.fault["loss_rate"]),
                               np.asarray(a.fault["loss_rate"]))


@multi_device
def test_sharded_spec_pipeline():
    """scenario_matrix(stacked=True) -> run_specs runs the policy axis
    through the sharded dispatch and returns BatchResults."""
    from repro.core.scenario import FabricSpec
    fab = FabricSpec(family="single", n_racks=1, nodes_per_rack=1,
                     gpus_per_node=4)
    wl = CollectiveSpec(kind="1d", total_bytes=4e6)
    specs = scenario_matrix([fab], [wl], ["dcqcn", "timely"], stacked=True)
    assert len(specs) == 1 and isinstance(specs[0].policy, tuple)
    sh = SweepRunner(CFG, mesh="auto")
    out = sh.run_specs(specs)
    assert len(out) == 1 and out[0].n == 2
    assert out[0].policy_of(0) == "dcqcn"
    vm_out = SweepRunner(CFG).run_specs(specs)
    np.testing.assert_allclose(out[0].completion_time,
                               vm_out[0].completion_time, rtol=1e-5)
    # ScenarioSpec.run routes tuple policies through the batched path too
    direct = specs[0].run(runner=sh)
    np.testing.assert_allclose(direct.completion_time,
                               out[0].completion_time, rtol=1e-5)


@multi_device
def test_sharded_calibration_kind():
    cfg = dataclasses.replace(CFG, max_steps=200)
    cal = sweep_mod.calibrate_backend(probe_flows=(24,), B=4, cfg=cfg,
                                      persist=False)
    try:
        assert "sharded" in cal.crossover
        assert any(p[0] == "sharded" for p in cal.probes)
    finally:
        sweep_mod.reset_calibration()
