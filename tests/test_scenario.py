"""Declarative scenario layer + dynamic FabricParams.

Covers the PR acceptance gate: a fabric-parameter grid (3 kmin/kmax x 3
xoff x 2 CC policies on the 32-GPU CLOS) runs through one
``SweepRunner.grid`` call per policy with ZERO recompiles after warmup,
and FabricParams defaults reproduce the seed-engine goldens.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.cc import get_policy
from repro.core.collectives import incast
from repro.core.engine import EngineConfig, FabricParams, Simulator, simulate
from repro.core.scenario import (TOPOLOGIES, CollectiveSpec, FabricSpec,
                                 IncastSpec, ScenarioSpec, scenario_matrix)
from repro.core.sweep import SweepRunner, compile_stats
from repro.core.topology import LINK_CLASSES, N_LINK_CLASSES, single_switch

GOLD = json.load(open(os.path.join(os.path.dirname(__file__), "golden",
                                   "engine_seed.json")))


# ---------------------------------------------------------------------------
# FabricSpec / registries
# ---------------------------------------------------------------------------

def test_fabric_spec_builds_and_caches():
    spec = FabricSpec(family="clos", n_racks=2, nodes_per_rack=2,
                      gpus_per_node=8)
    topo = spec.build()
    assert topo.n_gpus == 32 == spec.n_gpus
    # value-cached: an equal spec returns the same built object
    assert FabricSpec(family="clos", n_racks=2, nodes_per_rack=2,
                      gpus_per_node=8).build() is topo


def test_fabric_spec_oversubscription():
    full = FabricSpec(family="clos", nodes_per_rack=2, gpus_per_node=8)
    assert full.spine_count == 16           # one uplink per NIC downlink
    half = dataclasses.replace(full, oversubscription=2.0)
    assert half.spine_count == 8
    assert half.build().meta["n_spines"] == 8
    explicit = dataclasses.replace(full, n_spines=3)
    assert explicit.spine_count == 3


def test_unknown_topology_family():
    with pytest.raises(KeyError, match="unknown topology family"):
        FabricSpec(family="dragonfly").build()
    assert set(TOPOLOGIES) >= {"clos", "single"}


def test_workload_specs_build_schedules():
    topo = FabricSpec(family="single", n_racks=1, nodes_per_rack=1,
                      gpus_per_node=8).build()
    s = CollectiveSpec("a2a", 8e6, n_chunks=2).build_schedule(topo)
    assert s.n_flows == 8 * 7 * 2
    s = IncastSpec(n_senders=7, size_each=1e6).build_schedule(topo)
    assert s.n_flows == 7
    with pytest.raises(KeyError, match="unknown collective"):
        CollectiveSpec("nope", 8e6).build_schedule(topo)


def test_schedule_memoized_across_policies():
    """A per-policy spec list over one (FabricSpec, workload) must build
    the schedule once — build() returns the same object."""
    fab = FabricSpec(family="single", n_racks=1, nodes_per_rack=1,
                     gpus_per_node=8)
    wl = CollectiveSpec("a2a", 4e6, n_chunks=2)
    scheds = [ScenarioSpec(fab, wl, pol).build()[1]
              for pol in ("pfc", "dcqcn", "hpcc")]
    assert scheds[0] is scheds[1] is scheds[2]
    # a prebuilt-Topology fabric is uncached (no value identity) but works
    topo = fab.build()
    s = ScenarioSpec(topo, wl, "pfc").build()[1]
    assert s is not scheds[0]
    np.testing.assert_array_equal(s.size, scheds[0].size)


def test_scenario_matrix_names():
    specs = scenario_matrix(
        FabricSpec(family="clos", n_racks=1, nodes_per_rack=2, gpus_per_node=4),
        [CollectiveSpec("ring", 4e6), CollectiveSpec("2d", 4e6)],
        ["pfc", "dcqcn"])
    assert len(specs) == 4
    assert specs[0].name == "clos8_ring_pfc"
    assert {s.policy for s in specs} == {"pfc", "dcqcn"}


def test_spec_run_and_cc_param_validation():
    spec = ScenarioSpec(
        fabric=FabricSpec(family="single", n_racks=1, nodes_per_rack=1,
                          gpus_per_node=4),
        workload=IncastSpec(n_senders=3, size_each=1e6),
        policy="dcqcn", cc_params={"rai_frac": 0.05})
    cfg = EngineConfig(dt=1e-6, max_steps=600, max_extends=2, queue_stride=0)
    r = SweepRunner(cfg).run_spec(spec)
    assert r.finished
    bad = dataclasses.replace(spec, cc_params={"not_a_param": 1.0})
    with pytest.raises(ValueError, match="unknown"):
        SweepRunner(cfg).run_spec(bad)


# ---------------------------------------------------------------------------
# FabricParams semantics
# ---------------------------------------------------------------------------

def test_fabric_defaults_reproduce_seed_goldens():
    """Explicitly-passed default FabricParams must reproduce the seed
    engine's golden results (the old static-scalar behavior)."""
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 10e6)
    cfg = EngineConfig(dt=1e-6, max_steps=1500, max_extends=5)
    for pol in ("pfc", "dcqcn", "dctcp"):
        g = GOLD[f"incast_ss8/{pol}"]
        r = simulate(topo, sched, get_policy(pol), cfg,
                     fabric_params=FabricParams())
        np.testing.assert_allclose(r.completion_time, g["completion_time"],
                                   rtol=1e-5)
        t_gold = np.array([np.inf if v is None else v for v in g["t_finish"]])
        np.testing.assert_allclose(r.t_finish, t_gold, rtol=1e-5)
        np.testing.assert_allclose(r.pause_count, np.asarray(g["pause_count"]),
                                   rtol=1e-3, atol=1.0)


def test_per_class_arrays_match_scalars_bitwise():
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 5e6)
    cfg = EngineConfig(dt=1e-6, max_steps=1200, max_extends=2)
    sim = Simulator(topo, sched, get_policy("dcqcn"), cfg)
    r0 = sim.run()
    uniform = FabricParams(**{
        f: np.full(N_LINK_CLASSES,
                   float(np.asarray(getattr(FabricParams(), f))), np.float32)
        for f in FabricParams.FIELDS})
    r1 = sim.run(fabric_params=uniform)
    assert np.array_equal(r0.t_finish, r1.t_finish)
    assert np.array_equal(r0.pause_count, r1.pause_count)
    assert np.array_equal(r0.delivered, r1.delivered)


def test_with_class_targets_one_link_class():
    fab = FabricParams().with_class(xoff={"tor_down": 123.0})
    xoff = np.asarray(fab.xoff)
    assert xoff.shape == (N_LINK_CLASSES,)
    i = LINK_CLASSES.index("tor_down")
    assert xoff[i] == 123.0
    others = np.delete(xoff, i)
    assert (others == 1e6).all()
    # scalar leaves untouched
    assert np.asarray(fab.kmin).shape == ()


def test_fabric_params_change_physics_without_recompile():
    """Tight PFC thresholds must create pauses; and a fabric change must
    not grow any compile cache (the knobs are traced inputs)."""
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 5e6)
    cfg = EngineConfig(dt=1e-6, max_steps=1500, max_extends=2)
    sim = Simulator(topo, sched, get_policy("pfc"), cfg)
    base = sim.run()
    s0 = compile_stats()
    tight = sim.run(fabric_params=FabricParams(xoff=0.2e6, xon=0.15e6))
    assert compile_stats() == s0
    assert tight.pause_count.sum() > base.pause_count.sum()
    # ECN ramp position moves DCQCN's completion
    sim2 = Simulator(topo, sched, get_policy("dcqcn"), cfg)
    r_early = sim2.run(fabric_params=FabricParams(kmin=20e3, kmax=80e3))
    r_late = sim2.run(fabric_params=FabricParams(kmin=4e6, kmax=16e6))
    assert r_early.completion_time != r_late.completion_time


def test_soft_cost_differentiates_through_fabric():
    import jax
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 3e6)
    cfg = EngineConfig(dt=2e-6, max_steps=500, max_extends=0, queue_stride=0)
    sim = Simulator(topo, sched, get_policy("dcqcn"), cfg)
    cost = sim.soft_cost_fn()
    g = jax.grad(lambda f: cost(get_policy("dcqcn").params, f))(FabricParams())
    assert np.isfinite(np.asarray(g.kmin))
    assert float(np.abs(np.asarray(g.kmin))) > 0.0


# ---------------------------------------------------------------------------
# acceptance gate: joint fabric grid, zero recompiles after warmup
# ---------------------------------------------------------------------------

def test_fabric_grid_zero_recompiles_32gpu_clos():
    """3 kmin/kmax x 3 xoff x 2 CC policies on the 32-GPU CLOS: one
    ``grid`` call per policy, and after a same-shaped warmup the full
    sweep adds ZERO compiled executables."""
    fab = FabricSpec(family="clos", n_racks=2, nodes_per_rack=2,
                     gpus_per_node=8)
    assert fab.n_gpus == 32
    spec_of = {pol: ScenarioSpec(fab, CollectiveSpec("1d", 4e6, n_chunks=2),
                                 pol) for pol in ("dcqcn", "dctcp")}
    runner = SweepRunner(EngineConfig(dt=2e-6, max_steps=1200, max_extends=1,
                                      queue_stride=0))
    grids = dict(kmin=[100e3, 400e3, 800e3],
                 kmax=[400e3, 1600e3, 3200e3],
                 xoff=[0.5e6, 1e6, 2e6])
    warm_grids = {k: [v * 1.1 for v in vs] for k, vs in grids.items()}
    for pol, spec in spec_of.items():      # warmup: same shapes, other values
        runner.grid_spec(spec, fabric_grid=warm_grids)
    s0 = compile_stats()
    for pol, spec in spec_of.items():
        batch = runner.grid_spec(spec, fabric_grid=grids)
        assert batch.n == 27
        assert batch.finished.all()
        # every grid point is a distinct fabric
        pts = set(zip(batch.fabric["kmin"].tolist(),
                      batch.fabric["kmax"].tolist(),
                      batch.fabric["xoff"].tolist()))
        assert len(pts) == 27
    assert compile_stats() == s0, "fabric grid recompiled after warmup"


def test_grid_joint_cc_and_fabric_matches_serial():
    topo = single_switch(8)
    sched = incast(topo, list(range(1, 8)), 0, 2e6)
    cfg = EngineConfig(dt=1e-6, max_steps=900, max_extends=1, queue_stride=0)
    runner = SweepRunner(cfg)
    batch = runner.grid(topo, sched, "dcqcn",
                        {"rai_frac": [0.01, 0.05]},
                        fabric_grid={"xoff": [0.3e6, 1e6]})
    assert batch.n == 4
    for i in range(batch.n):
        serial = runner.run(topo, sched, get_policy("dcqcn"),
                            cc_params=batch.param_set(i), cfg=cfg,
                            fabric_params=batch.fabric_set(i))
        np.testing.assert_allclose(batch.t_finish[i], serial.t_finish,
                                   rtol=1e-5)
        np.testing.assert_allclose(batch.pause_count[i], serial.pause_count,
                                   rtol=1e-3, atol=1.0)


def test_grid_input_validation():
    topo = single_switch(4)
    sched = incast(topo, [1, 2], 0, 1e6)
    runner = SweepRunner(EngineConfig(dt=1e-6, max_steps=100, max_extends=0,
                                      queue_stride=0))
    with pytest.raises(ValueError, match="unknown fabric params"):
        runner.run_batch(topo, sched, "dcqcn",
                         stacked_fabric={"koff": np.array([1.0, 2.0])})
    with pytest.raises(ValueError, match="inconsistent batch"):
        runner.run_batch(topo, sched, "dcqcn",
                         {"rai_frac": np.array([0.01, 0.02])},
                         stacked_fabric={"xoff": np.array([1e6, 2e6, 3e6])})
    with pytest.raises(ValueError, match="empty"):
        runner.grid(topo, sched, "dcqcn", {})


def test_autotune_fabric_keys():
    from repro.core.autotune import autotune_spec
    spec = ScenarioSpec(
        fabric=FabricSpec(family="single", n_racks=1, nodes_per_rack=1,
                          gpus_per_node=5),
        workload=IncastSpec(n_senders=4, size_each=2e6),
        policy=get_policy("dcqcn"))
    cfg = EngineConfig(dt=2e-6, max_steps=400, max_extends=0, queue_stride=0)
    res = autotune_spec(spec, [], fabric_keys=["kmin"], steps=2,
                        cfg=cfg, population=2)
    assert res.fabric is not None
    assert float(np.asarray(res.fabric.kmin)) > 0
    assert len(res.history) == 2
    with pytest.raises(ValueError, match="unknown fabric params"):
        autotune_spec(spec, [], fabric_keys=["nope"], steps=1, cfg=cfg)
