"""Per-architecture smoke tests: reduced same-family config, one loss step
+ prefill/decode consistency on CPU.  (Deliverable f.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_model


def _batch(m, key, B=2, S=32):
    cfg = m.cfg
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.vlm_prefix_len:
        b["img"] = 0.1 * jax.random.normal(key, (B, cfg.vlm_prefix_len, cfg.d_model),
                                           jnp.bfloat16)
    if cfg.enc_dec:
        b["frames"] = 0.1 * jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_loss_and_shapes(arch, key):
    m = smoke_model(arch)
    params = m.init(key)
    batch = _batch(m, key)
    loss = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss)), (arch, loss)
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_grads_finite(arch, key):
    m = smoke_model(arch)
    params = m.init(key)
    batch = _batch(m, key)
    g = jax.jit(jax.grad(m.loss))(params, batch)
    leaves = jax.tree.leaves(g)
    assert leaves
    for leaf in leaves:
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, key):
    """decode_step after prefill(S) must match prefill(S+1)'s last logits."""
    m = smoke_model(arch)
    params = m.init(key)
    B, S = 2, 24
    batch = _batch(m, key, B, S + 1)
    toks = batch["tokens"]

    short = dict(batch, tokens=toks[:, :S])
    if m.cfg.enc_dec:  # encoder memory must be identical for both paths
        short["frames"] = batch["frames"]
    logits_s, cache = jax.jit(lambda p, b: m.prefill(p, b, max_len=S + 8))(params, short)
    logits_step, _ = jax.jit(m.decode_step)(params, cache, toks[:, S:S + 1])

    full = dict(batch, tokens=toks[:, :S + 1])
    logits_f, _ = jax.jit(lambda p, b: m.prefill(p, b, max_len=S + 9))(params, full)

    a = np.asarray(logits_step, np.float32)
    b = np.asarray(logits_f, np.float32)
    # same math via different kernels (blockwise/ring/chunked-scan vs decode
    # recurrences) in bf16 compute: allow small drift, require same argmax
    assert np.mean(np.abs(a - b)) < 0.05, arch
    assert (np.argmax(a, -1) == np.argmax(b, -1)).mean() >= 0.5, arch
