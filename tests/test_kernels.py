"""Per-kernel allclose vs ref.py oracles over shape/dtype sweeps
(interpret=True executes the Pallas body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.cc import FlowCtx, Signals, make_dcqcn
from repro.kernels.cc_update.ops import dcqcn_update
from repro.kernels.embedding_bag.ops import embedding_bag_stacked
from repro.kernels.embedding_bag.ref import embedding_bag_stacked_ref
from repro.kernels.flash_decode.ops import gqa_decode_attention
from repro.kernels.flash_decode.ref import flash_decode_ref

pytestmark = pytest.mark.kernel


# ---------------------------------------------------------------- embedding
@pytest.mark.parametrize("T,R,D,B,P", [(2, 16, 64, 2, 3), (4, 64, 64, 3, 60),
                                       (1, 8, 128, 2, 5), (3, 32, 96, 2, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_shapes(T, R, D, B, P, dtype, key):
    tables = jax.random.normal(key, (T, R, D), dtype)
    idx = jax.random.randint(key, (B, T, P), 0, R)
    out = embedding_bag_stacked(tables, idx)
    ref = embedding_bag_stacked_ref(tables, idx)
    assert out.dtype == ref.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@given(st.integers(1, 4), st.integers(1, 16), st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_embedding_bag_property(T, P, B):
    key = jax.random.PRNGKey(T * 100 + P * 10 + B)
    tables = jax.random.normal(key, (T, 32, 64), jnp.float32)
    idx = jax.random.randint(key, (B, T, P), 0, 32)
    out = embedding_bag_stacked(tables, idx)
    ref = embedding_bag_stacked_ref(tables, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- flash decode
@pytest.mark.parametrize("B,S,Hkv,G,D,bs", [
    (1, 256, 1, 1, 128, 128), (2, 512, 2, 4, 128, 256),
    (2, 384, 4, 2, 64, 128), (1, 1024, 2, 8, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_shapes(B, S, Hkv, G, D, bs, dtype, key):
    q = jax.random.normal(key, (B, 1, Hkv * G, D), dtype)
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D), dtype)
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D), dtype)
    lng = jnp.asarray([S - 17] + [S] * (B - 1), jnp.int32)
    out = gqa_decode_attention(q, kc, vc, lng, block_s=bs)
    ref = flash_decode_ref(q.reshape(B, Hkv, G, D), kc, vc, lng).reshape(B, 1, Hkv * G, D)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@given(st.integers(1, 3), st.sampled_from([128, 256, 512]), st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_flash_decode_property(B, S, cut):
    key = jax.random.PRNGKey(B * 1000 + S + cut)
    Hkv, G, D = 2, 2, 64
    q = jax.random.normal(key, (B, 1, Hkv * G, D), jnp.float32)
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D), jnp.float32)
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D), jnp.float32)
    lng = jnp.full((B,), max(1, S - cut), jnp.int32)
    out = gqa_decode_attention(q, kc, vc, lng, block_s=128)
    ref = flash_decode_ref(q.reshape(B, Hkv, G, D), kc, vc, lng).reshape(B, 1, Hkv * G, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------- cc update
@pytest.mark.parametrize("F", [7, 128, 300, 1000])
def test_cc_update_matches_policy(F, key):
    pol = make_dcqcn()
    line = jnp.full((F,), 25e9, jnp.float32)
    st_ = pol.init(FlowCtx.make(line, line * 2e-6))
    st_ = dict(st_, rc=st_["rc"] * jax.random.uniform(key, (F,), minval=0.05, maxval=1.0),
               alpha=jax.random.uniform(key, (F,), minval=0.1, maxval=1.0))
    ecn = jax.random.uniform(jax.random.PRNGKey(9), (F,), maxval=0.4)
    got = dcqcn_update(st_, ecn, line, 2e-3, pol.params)
    sig = Signals(ecn=ecn, rtt=jnp.zeros(F), util=jnp.zeros(F),
                  t=jnp.asarray(2e-3, jnp.float32), dt=jnp.float32(1e-6),
                  line=line, base_rtt=jnp.zeros(F))
    want, _, _ = pol.update(pol.params, st_, sig)
    for k in ("rc", "rt", "alpha", "t_cut", "t_inc", "t_alpha", "inc_count"):
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)
