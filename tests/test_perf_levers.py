"""Regression tests for the §Perf optimization levers: each lever must be
numerically equivalent (or within quantization tolerance) to its baseline.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.pytree import materialize
from repro.configs import smoke_config
from repro.models import rwkv as RWKV
from repro.models.model_api import Model


def test_rwkv_chunked_equals_scan(key):
    cfg = smoke_config("rwkv6-3b")
    cfgc = dataclasses.replace(cfg, rwkv_chunk=8)
    p = materialize(RWKV.rwkv6_defs(cfg), key)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y1, s1 = RWKV.rwkv6_time_mix(p["time"], x, cfg, None)
    y2, s2 = RWKV.rwkv6_time_mix(p["time"], x, cfgc, None)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1["S"]), np.asarray(s2["S"]),
                               rtol=1e-4, atol=1e-4)


def test_rwkv_chunked_with_incoming_state(key):
    cfg = smoke_config("rwkv6-3b")
    cfgc = dataclasses.replace(cfg, rwkv_chunk=8)
    p = materialize(RWKV.rwkv6_defs(cfg), key)
    B, D, H = 2, cfg.d_model, cfg.n_heads
    dk = D // H
    st = {"S": 0.3 * jax.random.normal(jax.random.PRNGKey(2), (B, H, dk, dk)),
          "tok": jnp.zeros((B, D))}
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, 32, D))
    y1, _ = RWKV.rwkv6_time_mix(p["time"], x, cfg, dict(st))
    y2, _ = RWKV.rwkv6_time_mix(p["time"], x, cfgc, dict(st))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)


def test_rwkv_chunked_grads_finite(key):
    cfg = dataclasses.replace(smoke_config("rwkv6-3b"), rwkv_chunk=8)
    p = materialize(RWKV.rwkv6_defs(cfg), key)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    g = jax.grad(lambda xx: RWKV.rwkv6_time_mix(p["time"], xx, cfg, None)[0].sum())(x)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_seq_parallel_same_loss_single_device(key):
    """seq_parallel only adds sharding constraints — on one device the
    loss must be bit-identical in structure (same math)."""
    cfg = smoke_config("tinyllama-1.1b")
    m0 = Model(cfg)
    m1 = Model(dataclasses.replace(cfg, seq_parallel=True))
    params = m0.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab)}
    l0 = float(jax.jit(m0.loss)(params, batch))
    l1 = float(jax.jit(m1.loss)(params, batch))
    assert l0 == pytest.approx(l1, rel=1e-6)


def test_flash_flag_same_loss(key):
    cfg = smoke_config("tinyllama-1.1b")
    m0 = Model(dataclasses.replace(cfg, block_q=256, block_k=256))
    m1 = Model(dataclasses.replace(cfg, flash_attention=True,
                                   block_q=256, block_k=256))
    params = m0.init(key)
    batch = {"tokens": jax.random.randint(key, (1, 2048), 0, cfg.vocab)}
    l0 = float(jax.jit(m0.loss)(params, batch))
    l1 = float(jax.jit(m1.loss)(params, batch))
    assert l0 == pytest.approx(l1, rel=2e-3)
