"""Resilient campaign runner: journal/resume equivalence, the retry
ladder, lane quarantine, deadline/watchdog enforcement, and the PR-10
sweep satellites (warning dedupe, calibration hardening, bounded compile
caches).

The crash/resume contract under test: a campaign killed mid-run and
resumed produces merged ``BatchResults`` bitwise-identical to an
uninterrupted run, with at most one chunk of work repeated.
"""
import json
import os
import signal
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.core import sweep as sweep_mod
from repro.core.campaign import (CampaignError, CampaignFingerprintMismatch,
                                 CampaignTask, _applicable_ladder,
                                 run_campaign, smoke_tasks)
from repro.core.collectives import allreduce_1d
from repro.core.engine import EngineConfig
from repro.core.faults import LaneStatus, classify_lane
from repro.core.sweep import (BackendCalibration, SweepRunner,
                              load_calibration, reset_unhealthy_warnings,
                              save_calibration)
from repro.core.topology import single_switch

pytestmark = pytest.mark.campaign

CFG = EngineConfig(dt=2e-6, max_steps=600, max_extends=1, queue_stride=0)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

RESULT_ARRAYS = ("completion_time", "t_finish", "pause_count", "delivered",
                 "soft_cost", "finished", "diverged", "deadlock_step",
                 "storm_step", "extend_exhausted")


def scenario(n=4, mb=4e6):
    topo = single_switch(n)
    return topo, allreduce_1d(topo, list(range(n)), mb)


def one_task(n_lanes=12, name="dcqcn_rai"):
    topo, sched = scenario()
    grid = np.geomspace(0.005, 0.2, n_lanes).astype(np.float32)
    return CampaignTask(name, topo, sched, "dcqcn",
                        stacked_params={"rai_frac": grid})


def assert_batches_bitwise(a, b):
    for k in RESULT_ARRAYS:
        va, vb = getattr(a, k), getattr(b, k)
        assert np.array_equal(np.asarray(va), np.asarray(vb),
                              equal_nan=True), f"{k} differs"


# ---------------------------------------------------------------------------
# happy path + manifest schema
# ---------------------------------------------------------------------------

def test_campaign_completes_with_manifest(tmp_path):
    task = one_task()
    res = run_campaign([task], "happy", out_dir=str(tmp_path), cfg=CFG,
                       chunk_lanes=4)
    assert res.status == "complete" and res.ok
    m = res.manifest
    assert m["coverage"] == 1.0
    ts = m["tasks"]["dcqcn_rai"]
    assert ts["n_chunks"] == 3 and ts["coverage"] == 1.0
    assert [c["status"] for c in ts["chunks"]] == ["done"] * 3
    assert all(c["attempts"] == 1 and not c["demotions"]
               for c in ts["chunks"])
    assert ts["uncovered_lanes"] == [] and ts["lane_status"] == {"ok": 12}
    # the manifest is on disk (atomic write) and json-round-trips
    on_disk = json.load(open(os.path.join(res.out_dir, "manifest.json")))
    assert on_disk["fingerprint"] == m["fingerprint"]
    assert on_disk["status"] == "complete"
    # journal holds one .npz per chunk
    files = sorted(os.listdir(os.path.join(res.out_dir, "journal")))
    assert [f for f in files if f.endswith(".npz")] == [
        f"dcqcn_rai__c{i:04d}.npz" for i in range(3)]
    # merged results == a direct run_batch (journal merge is lossless)
    direct = SweepRunner(CFG).run_batch(
        task.topo, task.sched, "dcqcn", task.stacked_params)
    assert_batches_bitwise(res.results["dcqcn_rai"], direct)


def test_campaign_refuses_unnamed_overwrite_and_fresh(tmp_path):
    task = one_task()
    run_campaign([task], "c", out_dir=str(tmp_path), cfg=CFG, chunk_lanes=4)
    with pytest.raises(CampaignError, match="resume=True"):
        run_campaign([task], "c", out_dir=str(tmp_path), cfg=CFG,
                     chunk_lanes=4)
    res = run_campaign([task], "c", out_dir=str(tmp_path), cfg=CFG,
                       chunk_lanes=4, fresh=True)
    assert res.ok


def test_fingerprint_mismatch_raises(tmp_path):
    run_campaign([one_task()], "fp", out_dir=str(tmp_path), cfg=CFG,
                 chunk_lanes=4)
    changed = one_task()
    changed.stacked_params = {
        "rai_frac": changed.stacked_params["rai_frac"] * 2.0}
    with pytest.raises(CampaignFingerprintMismatch):
        run_campaign([changed], "fp", out_dir=str(tmp_path), cfg=CFG,
                     chunk_lanes=4, resume=True)


# ---------------------------------------------------------------------------
# crash / resume bitwise equivalence
# ---------------------------------------------------------------------------

def test_crash_resume_bitwise_identical(tmp_path):
    """Injected mid-campaign crash (a BaseException the retry ladder must
    NOT swallow), then resume: merged results bitwise-equal to an
    uninterrupted run, exactly the journaled chunks are skipped."""
    task = one_task()
    ref = run_campaign([one_task()], "ref", out_dir=str(tmp_path / "a"),
                       cfg=CFG, chunk_lanes=4)

    calls = {"n": 0}

    def hook(lo, hi, B):
        calls["n"] += 1
        if calls["n"] > 2:
            raise KeyboardInterrupt("injected crash")

    runner = SweepRunner(cfg=CFG, chunk_lanes=4, dispatch_hook=hook)
    with pytest.raises(KeyboardInterrupt):
        run_campaign([task], "crash", out_dir=str(tmp_path / "b"),
                     runner=runner, cfg=CFG, chunk_lanes=4)
    journal = tmp_path / "b" / "crash" / "journal"
    done = sorted(f for f in os.listdir(journal) if f.endswith(".npz"))
    assert len(done) == 2              # at most one in-flight chunk lost

    res = run_campaign([one_task()], "crash", out_dir=str(tmp_path / "b"),
                       cfg=CFG, chunk_lanes=4, resume=True)
    assert res.ok
    replayed = [c["status"] for c in
                res.manifest["tasks"]["dcqcn_rai"]["chunks"]]
    assert replayed == ["replayed", "replayed", "done"]
    assert_batches_bitwise(res.results["dcqcn_rai"],
                           ref.results["dcqcn_rai"])


def test_subprocess_sigkill_resume(tmp_path):
    """The full-fidelity variant: a real SIGKILL of the CLI mid-campaign,
    then resume completes with full coverage and results bitwise-equal to
    an uninterrupted in-process run of the same smoke campaign."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_CALIBRATION_CACHE="0")
    cmd = [sys.executable, os.path.join(REPO, "scripts", "run_campaign.py"),
           "--smoke", "--out", str(tmp_path / "kill"),
           "--chunk-lanes", "4", "--kill-after-chunks", "2"]
    p = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=600)
    assert p.returncode == -signal.SIGKILL or p.returncode == 137, p.stderr
    journal = tmp_path / "kill" / "smoke" / "journal"
    assert len([f for f in os.listdir(journal) if f.endswith(".npz")]) == 2

    p2 = subprocess.run(cmd[:-2] + ["--resume", "--expect-full"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert p2.returncode == 0, p2.stdout + p2.stderr

    # bitwise equivalence against an uninterrupted in-process run
    tasks, cfg = smoke_tasks()
    ref = run_campaign(tasks, "smoke", out_dir=str(tmp_path / "ref"),
                       cfg=cfg, chunk_lanes=4)
    resumed = run_campaign(tasks, name="smoke",
                           out_dir=str(tmp_path / "kill"), cfg=cfg,
                           chunk_lanes=4, resume=True)
    assert resumed.ok
    for tname in ref.results:
        assert_batches_bitwise(resumed.results[tname], ref.results[tname])


def test_corrupt_journal_chunk_rerun(tmp_path):
    """A truncated chunk file (pre-atomic-rename kill, disk trouble) is
    warned about and re-run on resume, not fatal."""
    ref = run_campaign([one_task()], "corrupt", out_dir=str(tmp_path),
                       cfg=CFG, chunk_lanes=4)
    cpath = os.path.join(ref.out_dir, "journal", "dcqcn_rai__c0001.npz")
    with open(cpath, "wb") as f:
        f.write(b"\x00truncated")
    with pytest.warns(RuntimeWarning, match="unreadable journal chunk"):
        res = run_campaign([one_task()], "corrupt", out_dir=str(tmp_path),
                           cfg=CFG, chunk_lanes=4, resume=True)
    assert res.ok
    statuses = [c["status"] for c in
                res.manifest["tasks"]["dcqcn_rai"]["chunks"]]
    assert statuses == ["replayed", "done", "replayed"]
    assert_batches_bitwise(res.results["dcqcn_rai"],
                           ref.results["dcqcn_rai"])


# ---------------------------------------------------------------------------
# retry ladder
# ---------------------------------------------------------------------------

def test_retry_ladder_demotion_order(tmp_path):
    """Injected dispatch failures walk the ladder in order, every
    demotion recorded; the serial bottom rung bypasses the failing
    dispatch hook (it is the vmap dispatch that 'OOMs') and completes."""
    task = one_task()
    ladder = _applicable_ladder(SweepRunner(CFG), CFG)
    assert ladder == ("half_chunk", "serial")   # CPU, no mesh, jnp step

    def hook(lo, hi, B):
        raise RuntimeError("injected OOM")

    runner = SweepRunner(cfg=CFG, chunk_lanes=4, dispatch_hook=hook)
    res = run_campaign([task], "ladder", out_dir=str(tmp_path),
                       runner=runner, cfg=CFG, chunk_lanes=4,
                       max_retries=3, backoff_s=0.0)
    assert res.ok and res.status == "complete"
    ts = res.manifest["tasks"]["dcqcn_rai"]
    # chunk 0 walked the full ladder: vmap fail -> half_chunk fail ->
    # serial success; demotion level then sticks for chunks 1-2
    assert [d["rung"] for d in ts["demotions"]] == ["half_chunk", "serial"]
    assert all(d["chunk"] == 0 for d in ts["demotions"])
    c0 = ts["chunks"][0]
    assert c0["attempts"] == 3 and c0["demotions"] == ["half_chunk",
                                                       "serial"]
    assert all(c["status"] == "done" for c in ts["chunks"])
    assert all("injected OOM" in d["after_error"] for d in ts["demotions"])
    # serial-rung results agree with the healthy vmap run
    direct = SweepRunner(CFG).run_batch(
        task.topo, task.sched, "dcqcn", task.stacked_params)
    np.testing.assert_allclose(res.results["dcqcn_rai"].completion_time,
                               direct.completion_time, rtol=1e-5)


def test_retry_budget_exhausted_marks_partial(tmp_path):
    """With too few retries to reach a working rung, the chunk is marked
    failed (never silent) and the campaign continues: later chunks ride
    the sticky demotion level and succeed, uncovered lanes are NaN-filled
    and listed."""

    def hook(lo, hi, B):
        raise RuntimeError("injected OOM")

    runner = SweepRunner(cfg=CFG, chunk_lanes=4, dispatch_hook=hook)
    res = run_campaign([one_task()], "exhaust", out_dir=str(tmp_path),
                       runner=runner, cfg=CFG, chunk_lanes=4,
                       max_retries=1, backoff_s=0.0)
    assert res.status == "partial" and not res.ok
    ts = res.manifest["tasks"]["dcqcn_rai"]
    assert ts["chunks"][0]["status"] == "failed"
    assert len(ts["chunks"][0]["attempts"]) == 2
    # chunks 1-2 start at the sticky level, reach serial, and succeed
    assert [c["status"] for c in ts["chunks"][1:]] == ["done", "done"]
    assert ts["uncovered_lanes"] == [0, 1, 2, 3]
    assert ts["coverage"] == pytest.approx(8 / 12)
    batch = res.results["dcqcn_rai"]
    assert np.isnan(batch.completion_time[:4]).all()
    assert np.isfinite(batch.completion_time[4:]).all()
    assert res.manifest["coverage"] == pytest.approx(8 / 12)


# ---------------------------------------------------------------------------
# lane quarantine
# ---------------------------------------------------------------------------

def test_quarantine_relaxed_budget_heals_lanes(tmp_path):
    """Lanes that exhaust a too-tight step budget are re-dispatched once
    with max_steps * quarantine_relax and patched in when they heal."""
    topo, sched = scenario()
    tight = EngineConfig(dt=2e-6, max_steps=60, max_extends=0,
                         queue_stride=0)
    task = CampaignTask("tight", topo, sched, "dcqcn",
                        stacked_params={"rai_frac": np.asarray(
                            [0.01, 0.03, 0.1, 0.2], np.float32)})
    res = run_campaign([task], "quar", out_dir=str(tmp_path), cfg=tight,
                       chunk_lanes=4, quarantine_relax=32.0)
    q = res.manifest["tasks"]["tight"]["quarantine"]
    assert q is not None and q["status"] == "done"
    assert q["lanes"] == [0, 1, 2, 3]
    assert q["before"] == ["exhausted"] * 4
    assert q["after"] == ["ok"] * 4 and q["patched"] == [0, 1, 2, 3]
    batch = res.results["tight"]
    assert batch.lane_status() == ["ok"] * 4
    assert bool(batch.finished.all())
    # the quarantine retry is journaled too: a resume replays it
    res2 = run_campaign([task], "quar", out_dir=str(tmp_path), cfg=tight,
                        chunk_lanes=4, quarantine_relax=32.0, resume=True)
    assert res2.manifest["tasks"]["tight"]["quarantine"]["status"] == \
        "replayed"
    assert_batches_bitwise(res2.results["tight"], batch)


def test_quarantine_off_leaves_lanes_flagged(tmp_path):
    topo, sched = scenario()
    tight = EngineConfig(dt=2e-6, max_steps=60, max_extends=0,
                         queue_stride=0)
    task = CampaignTask("tight", topo, sched, "dcqcn",
                        stacked_params={"rai_frac": np.asarray(
                            [0.01, 0.03], np.float32)})
    res = run_campaign([task], "noquar", out_dir=str(tmp_path), cfg=tight,
                       quarantine=False)
    assert res.manifest["tasks"]["tight"]["quarantine"] is None
    assert res.results["tight"].lane_status() == ["exhausted"] * 2
    assert res.status == "complete"    # unhealthy-but-covered is complete


# ---------------------------------------------------------------------------
# deadline / watchdog
# ---------------------------------------------------------------------------

def test_deadline_checkpoints_partial_manifest(tmp_path):
    res = run_campaign([one_task()], "ddl", out_dir=str(tmp_path), cfg=CFG,
                       chunk_lanes=4, deadline_s=0.0)
    assert res.status == "deadline" and not res.ok
    assert res.manifest["coverage"] == 0.0
    on_disk = json.load(open(os.path.join(res.out_dir, "manifest.json")))
    assert on_disk["status"] == "deadline"
    assert np.isnan(res.results["dcqcn_rai"].completion_time).all()
    # ...and the journaled prefix resumes to completion without a deadline
    res2 = run_campaign([one_task()], "ddl", out_dir=str(tmp_path),
                        cfg=CFG, chunk_lanes=4, resume=True)
    assert res2.ok


def test_chunk_watchdog_timeout_checkpoints(tmp_path):
    res = run_campaign([one_task()], "wdt", out_dir=str(tmp_path), cfg=CFG,
                       chunk_lanes=4, chunk_timeout_s=1e-4)
    assert res.status == "chunk_timeout" and not res.ok
    ts = res.manifest["tasks"]["dcqcn_rai"]
    assert ts["chunks"][0]["status"] == "timeout"
    assert "watchdog" in ts["chunks"][0]["attempts"][0]["error"]


# ---------------------------------------------------------------------------
# typed lane status (tentpole satellite: enum instead of ad-hoc strings)
# ---------------------------------------------------------------------------

def test_lane_status_is_typed_enum():
    topo, sched = scenario()
    batch = SweepRunner(CFG).run_batch(topo, sched, "dcqcn",
                                       {"rai_frac": np.asarray(
                                           [0.01, 0.05], np.float32)})
    statuses = batch.lane_status()
    assert all(isinstance(s, LaneStatus) for s in statuses)
    assert statuses == ["ok", "ok"]            # str-subclass compatibility
    assert json.loads(json.dumps(statuses)) == ["ok", "ok"]
    assert f"{statuses[0]}" == "ok"
    r = SweepRunner(CFG).run(topo, sched, "dcqcn")
    assert isinstance(r.status, LaneStatus) and r.status == "ok"
    # precedence: diverged > deadlocked > exhausted
    assert classify_lane(True, True, False) is LaneStatus.DIVERGED
    assert classify_lane(False, True, True) is LaneStatus.DEADLOCKED
    assert classify_lane(False, False, False) is LaneStatus.EXHAUSTED


# ---------------------------------------------------------------------------
# sweep satellites: warning dedupe, calibration hardening, cache bounds
# ---------------------------------------------------------------------------

def test_unhealthy_warning_names_lanes_and_dedupes():
    topo, sched = scenario()
    tight = EngineConfig(dt=2e-6, max_steps=60, max_extends=0,
                         queue_stride=0)
    runner = SweepRunner(tight)
    stacked = {"rai_frac": np.asarray([0.01, 0.03], np.float32)}
    reset_unhealthy_warnings()
    with pytest.warns(RuntimeWarning, match=r"exhausted: lanes \[0, 1\]"):
        runner.run_batch(topo, sched, "dcqcn", stacked)
    # identical regime again: deduplicated
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        runner.run_batch(topo, sched, "dcqcn", stacked)
    # re-armed after reset
    reset_unhealthy_warnings()
    with pytest.warns(RuntimeWarning, match="lanes unhealthy"):
        runner.run_batch(topo, sched, "dcqcn", stacked)


def test_calibration_corrupt_cache_ignored(tmp_path):
    path = str(tmp_path / "repro_calibration_cpu.json")
    with open(path, "w") as f:
        f.write('{"backend": "cpu", "crossover": {"sweep": ')   # truncated
    with pytest.warns(RuntimeWarning, match="corrupt calibration cache"):
        assert load_calibration("cpu", path=path) is None
    # valid JSON, wrong shape: also log-and-ignore
    import jax
    with open(path, "w") as f:
        json.dump({"backend": "cpu", "jax": jax.__version__,
                   "n_devices": len(jax.devices()),
                   "probes": [{"bogus": 1}]}, f)
    with pytest.warns(RuntimeWarning, match="malformed calibration cache"):
        assert load_calibration("cpu", path=path) is None
    # absent file stays silent (normal cold start)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert load_calibration("cpu",
                                path=str(tmp_path / "nope.json")) is None


def test_save_calibration_atomic(tmp_path):
    cal = BackendCalibration(backend="cpu", source="measured",
                             crossover={"sweep": 123.0})
    path = str(tmp_path / "cal.json")
    assert save_calibration(cal, path=path) == path
    assert not [f for f in os.listdir(tmp_path) if ".tmp." in f]
    loaded = load_calibration("cpu", path=path)
    assert loaded is not None and loaded.crossover["sweep"] == 123.0


def test_compile_caches_bounded_with_eviction_counts():
    old_max = sweep_mod.BATCH_CACHE_MAX
    saved = dict(sweep_mod._BATCH_CACHE)
    before = sweep_mod._CACHE_EVICTIONS["batch"]
    try:
        sweep_mod._BATCH_CACHE.clear()
        sweep_mod.BATCH_CACHE_MAX = 2
        for i in range(4):
            sweep_mod._cache_put(sweep_mod._BATCH_CACHE, f"k{i}", i,
                                 "batch", sweep_mod.BATCH_CACHE_MAX)
        assert len(sweep_mod._BATCH_CACHE) == 2
        assert list(sweep_mod._BATCH_CACHE) == ["k2", "k3"]   # FIFO
        stats = sweep_mod.compile_stats()
        assert stats["evictions"]["batch"] == before + 2
        assert "shard" in stats["evictions"]
    finally:
        sweep_mod.BATCH_CACHE_MAX = old_max
        sweep_mod._BATCH_CACHE.clear()
        sweep_mod._BATCH_CACHE.update(saved)


def test_campaign_task_validation():
    topo, sched = scenario()
    with pytest.raises(CampaignError, match="no stacked axes"):
        CampaignTask("empty", topo, sched, "dcqcn").n_lanes
    with pytest.raises(CampaignError, match="inconsistent"):
        CampaignTask("bad", topo, sched, "dcqcn",
                     stacked_params={"rai_frac": np.zeros(3)},
                     stacked_fault={"loss_rate": np.zeros(4)}).n_lanes
    with pytest.raises(CampaignError, match="duplicate task names"):
        run_campaign([one_task(name="a"), one_task(name="a")], "dup",
                     out_dir="/tmp/never-created-xyz")
