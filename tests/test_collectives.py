"""System layer: collective decomposition correctness."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.collectives import (allreduce_1d, allreduce_2d, alltoall,
                                    collective_bytes_on_nics)
from repro.core.topology import clos, single_switch


@pytest.fixture(scope="module")
def topo():
    return clos(n_racks=2, nodes_per_rack=2, gpus_per_node=4)


def test_alltoall_total_bytes(topo):
    gpus = list(range(16))
    S = 64e6
    sched = alltoall(topo, gpus, S)
    # direct a2a moves (P-1)/P of the data per GPU -> total = S*(P-1)
    np.testing.assert_allclose(sched.total_bytes(), S * 15, rtol=1e-6)


def test_allreduce_1d_total_bytes(topo):
    gpus = list(range(16))
    S = 64e6
    sched = allreduce_1d(topo, gpus, S)
    # RS + AG, each (P-1)*S/P per GPU summed -> 2*S*(P-1)
    np.testing.assert_allclose(sched.total_bytes(), 2 * S * 15, rtol=1e-6)


def test_2d_sends_less_through_nics(topo):
    gpus = list(range(16))
    S = 64e6
    b1 = collective_bytes_on_nics(allreduce_1d(topo, gpus, S), topo)
    b2 = collective_bytes_on_nics(allreduce_2d(topo, gpus, S), topo)
    assert b2 < b1 / 2.5, (b1, b2)  # the paper's F4 traffic claim


def test_chunks_are_chained(topo):
    sched = alltoall(topo, list(range(16)), 16e6, n_chunks=4)
    # chunk c depends on chunk c-1
    assert sched.n_groups == 4
    deps = {}
    for f in range(sched.n_flows):
        deps.setdefault(sched.group[f], set()).add(sched.dep[f])
    assert deps[0] == {-1}
    for c in range(1, 4):
        assert deps[c] == {c - 1}


def test_2d_stage_chain(topo):
    sched = allreduce_2d(topo, list(range(16)), 16e6, n_chunks=2)
    names = sched.group_names
    idx = {n: i for i, n in enumerate(names)}
    for c in range(2):
        for a, b in [("rs_local", "rs_xnode"), ("rs_xnode", "ag_xnode"),
                     ("ag_xnode", "ag_local")]:
            ga, gb = idx[f"c{c}_{a}"], idx[f"c{c}_{b}"]
            deps_b = {sched.dep[f] for f in range(sched.n_flows)
                      if sched.group[f] == gb}
            assert deps_b == {ga}


@given(st.integers(1, 3).map(lambda x: 2 ** x))
@settings(max_examples=6, deadline=None)
def test_property_a2a_bytes_scale(chunks):
    topo = single_switch(8)
    sched = alltoall(topo, list(range(8)), 8e6, n_chunks=chunks)
    np.testing.assert_allclose(sched.total_bytes(), 8e6 * 7 / 8 * 8, rtol=1e-6)


def test_ecmp_spreads_spine_choice():
    topo = clos(n_racks=4, nodes_per_rack=2, gpus_per_node=4, n_spines=4)
    sched = alltoall(topo, list(range(32)), 32e6, n_chunks=1)
    spine_links = set(topo.meta["tor_up"].flatten().tolist())
    used = {}
    for f in range(sched.n_flows):
        for l in sched.path[f]:
            if int(l) in spine_links:
                used[int(l)] = used.get(int(l), 0) + 1
    # every TOR->spine uplink should carry some flows (ECMP balance)
    assert len(used) == len(spine_links)
    counts = np.asarray(list(used.values()))
    assert counts.max() / max(counts.min(), 1) < 4
