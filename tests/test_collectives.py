"""System layer: collective decomposition correctness."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.collectives import (COLLECTIVES, ScheduleBuilder,
                                    allreduce_1d, allreduce_2d,
                                    allreduce_hring, allreduce_ring,
                                    alltoall, collective_bytes_on_nics,
                                    get_collective)
from repro.core.topology import _ecmp_hash, clos, route, single_switch


@pytest.fixture(scope="module")
def topo():
    return clos(n_racks=2, nodes_per_rack=2, gpus_per_node=4)


def test_alltoall_total_bytes(topo):
    gpus = list(range(16))
    S = 64e6
    sched = alltoall(topo, gpus, S)
    # direct a2a moves (P-1)/P of the data per GPU -> total = S*(P-1)
    np.testing.assert_allclose(sched.total_bytes(), S * 15, rtol=1e-6)


def test_allreduce_1d_total_bytes(topo):
    gpus = list(range(16))
    S = 64e6
    sched = allreduce_1d(topo, gpus, S)
    # RS + AG, each (P-1)*S/P per GPU summed -> 2*S*(P-1)
    np.testing.assert_allclose(sched.total_bytes(), 2 * S * 15, rtol=1e-6)


def test_2d_sends_less_through_nics(topo):
    gpus = list(range(16))
    S = 64e6
    b1 = collective_bytes_on_nics(allreduce_1d(topo, gpus, S), topo)
    b2 = collective_bytes_on_nics(allreduce_2d(topo, gpus, S), topo)
    assert b2 < b1 / 2.5, (b1, b2)  # the paper's F4 traffic claim


def test_chunks_are_chained(topo):
    sched = alltoall(topo, list(range(16)), 16e6, n_chunks=4)
    # chunk c depends on chunk c-1
    assert sched.n_groups == 4
    deps = {}
    for f in range(sched.n_flows):
        deps.setdefault(sched.group[f], set()).add(sched.dep[f])
    assert deps[0] == {-1}
    for c in range(1, 4):
        assert deps[c] == {c - 1}


def test_2d_stage_chain(topo):
    sched = allreduce_2d(topo, list(range(16)), 16e6, n_chunks=2)
    names = sched.group_names
    idx = {n: i for i, n in enumerate(names)}
    for c in range(2):
        for a, b in [("rs_local", "rs_xnode"), ("rs_xnode", "ag_xnode"),
                     ("ag_xnode", "ag_local")]:
            ga, gb = idx[f"c{c}_{a}"], idx[f"c{c}_{b}"]
            deps_b = {sched.dep[f] for f in range(sched.n_flows)
                      if sched.group[f] == gb}
            assert deps_b == {ga}


@given(st.integers(1, 3).map(lambda x: 2 ** x))
@settings(max_examples=6, deadline=None)
def test_property_a2a_bytes_scale(chunks):
    topo = single_switch(8)
    sched = alltoall(topo, list(range(8)), 8e6, n_chunks=chunks)
    np.testing.assert_allclose(sched.total_bytes(), 8e6 * 7 / 8 * 8, rtol=1e-6)


def test_ecmp_spreads_spine_choice():
    topo = clos(n_racks=4, nodes_per_rack=2, gpus_per_node=4, n_spines=4)
    sched = alltoall(topo, list(range(32)), 32e6, n_chunks=1)
    spine_links = set(topo.meta["tor_up"].flatten().tolist())
    used = {}
    for f in range(sched.n_flows):
        for lk in sched.path[f]:
            if int(lk) in spine_links:
                used[int(lk)] = used.get(int(lk), 0) + 1
    # every TOR->spine uplink should carry some flows (ECMP balance)
    assert len(used) == len(spine_links)
    counts = np.asarray(list(used.values()))
    assert counts.max() / max(counts.min(), 1) < 4


def test_ecmp_hash_buckets_chi_square():
    """_ecmp_hash over ScheduleBuilder-style keys must spread uniformly:
    a (loose) chi-square bound on the spine buckets."""
    k = 8
    n = 4000
    counts = np.zeros(k)
    for src in range(50):
        for dst in range(80):
            key = (src * 131071 + dst * 8191 + src * 524287) & 0x7FFFFFFF
            counts[_ecmp_hash(key) % k] += 1
    assert counts.sum() == n
    exp = n / k
    chi2 = float(((counts - exp) ** 2 / exp).sum())
    # dof = 7; a uniform hash lands ~7 with fluctuation — 3x the bucket
    # count is a deliberately loose bound that still catches a broken mix
    assert chi2 < 3 * k, (chi2, counts.tolist())
    assert (counts > 0).all()


def test_route_uses_every_spine_for_cross_rack():
    topo = clos(n_racks=2, nodes_per_rack=2, gpus_per_node=4, n_spines=8)
    spine_links = set(topo.meta["tor_up"].flatten().tolist())
    hit = set()
    for src in range(8):               # rack 0
        for dst in range(8, 16):       # rack 1
            for salt in range(4):
                key = (src * 131071 + dst * 8191 + salt * 524287) & 0x7FFFFFFF
                for lk in route(topo, src, dst, key):
                    if lk in spine_links:
                        hit.add(lk)
    # rack-0 ToR has 8 uplinks; cross-rack flows must reach all of them
    assert len(hit) == 8


@pytest.mark.parametrize("name", sorted({f.__name__ for f in COLLECTIVES.values()}))
def test_registered_collectives_conserve_bytes(name):
    """Every registered collective must deliver exactly the bytes it
    schedules (engine byte conservation end-to-end)."""
    from repro.core.cc import get_policy
    from repro.core.engine import EngineConfig, simulate
    topo = clos(n_racks=1, nodes_per_rack=2, gpus_per_node=4)
    sched = get_collective(name)(topo, list(range(8)), 2e6, n_chunks=2)
    assert sched.total_bytes() > 0
    cfg = EngineConfig(dt=1e-6, max_steps=2500, max_extends=3, queue_stride=0)
    r = simulate(topo, sched, get_policy("pfc"), cfg)
    assert r.finished, name
    np.testing.assert_allclose(r.delivered.sum(), sched.size.sum(), rtol=2e-3)


def test_ring_nic_bytes_at_most_1d(topo):
    """Topology-aware ring keeps NIC traffic <= the direct 1D algorithm
    (same total bytes, neighbor hops mostly on the scale-up fabric)."""
    gpus = list(range(16))
    S = 64e6
    ring = allreduce_ring(topo, gpus, S)
    d1 = allreduce_1d(topo, gpus, S)
    np.testing.assert_allclose(ring.total_bytes(), d1.total_bytes(), rtol=1e-6)
    assert collective_bytes_on_nics(ring, topo) <= \
        collective_bytes_on_nics(d1, topo)
    # hierarchical ring matches 2D's NIC traffic profile
    hring = allreduce_hring(topo, gpus, S)
    d2 = allreduce_2d(topo, gpus, S)
    np.testing.assert_allclose(collective_bytes_on_nics(hring, topo),
                               collective_bytes_on_nics(d2, topo), rtol=1e-6)


def test_ring_step_chain(topo):
    """Ring RS/AG steps serialize: step s depends on step s-1."""
    sched = allreduce_ring(topo, list(range(8)), 8e6, n_chunks=2)
    # 2 chunks x (7 RS + 7 AG) step-groups
    assert sched.n_groups == 2 * 14
    deps = {}
    for f in range(sched.n_flows):
        deps.setdefault(int(sched.group[f]), set()).add(int(sched.dep[f]))
    for g, d in deps.items():
        assert len(d) == 1
        assert next(iter(d)) < g or next(iter(d)) == -1


def test_builder_rejects_bad_deps():
    topo = single_switch(4)
    b = ScheduleBuilder(topo)
    g0 = b.new_group("first")
    b.add_flow(0, 1, 1e6, g0, dep=g0)      # self-dependency
    with pytest.raises(ValueError, match="its own group"):
        b.build()
    b = ScheduleBuilder(topo)
    g0 = b.new_group("early")
    g1 = b.new_group("late")
    b.add_flow(0, 1, 1e6, g0, dep=g1)      # forward reference
    with pytest.raises(ValueError, match="'late'"):
        b.build()
    b = ScheduleBuilder(topo)
    g0 = b.new_group("only")
    b.add_flow(0, 1, 1e6, g0, dep=7)       # dangling group id
    with pytest.raises(ValueError, match="undefined group 7"):
        b.build()
