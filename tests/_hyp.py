"""Optional-hypothesis shim.

Import ``given`` / ``settings`` / ``st`` from here instead of from
``hypothesis`` directly: when hypothesis is not installed the decorators
turn into ``pytest.mark.skip`` so property-based tests auto-skip while the
rest of the module still collects and runs.
"""
try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    class settings:  # noqa: N801 - mirrors the hypothesis API
        def __init__(self, *_a, **_k):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*_a, **_k):
            pass

        @staticmethod
        def load_profile(*_a, **_k):
            pass

    class _Strategy:
        """Inert stand-in supporting the chained strategy API."""

        def __call__(self, *_a, **_k):
            return self

        def __getattr__(self, _name):
            return self

    class _St:
        def __getattr__(self, _name):
            return _Strategy()

    st = _St()
