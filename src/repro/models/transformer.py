"""Unified model assembly for the whole zoo.

A model = embedding + a list of *groups*.  Each group is a stack of
identical *periods* scanned with ``lax.scan`` (weights stacked on a leading
``layers`` dim), where a period is a short tuple of heterogeneous layers —
e.g. gemma2's ("local attn", "global attn") pair, gemma3's 5 local + 1
global, zamba2's 6 mamba blocks + 1 shared-attention application.  This
keeps the HLO small (one while-loop per group) while giving every sub-layer
its exact structure (no masked-FLOP conditionals).

Layer kinds (mixer, ffn):
  ("gqa_g","mlp")  global causal GQA      ("gqa_l","mlp")  sliding window
  ("mla","mlp"|"moe")  deepseek latent attention (+MoE)
  ("mamba", None)  mamba2                  ("rwkv6","rwkv_ffn")  rwkv6
  ("shared_gqa","mlp")  zamba2 shared block (params NOT scanned)
  ("enc_attn","mlp")  bidirectional        ("dec_attn","mlp")  causal+cross
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.pytree import ParamDef
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import rwkv as RWKV
from repro.models import ssm as SSM


# ---------------------------------------------------------------------------
# group construction
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Group:
    kinds: tuple[tuple[str, str | None], ...]   # one (mixer, ffn) per sub-layer
    n: int                                      # number of scanned periods


def build_groups(cfg) -> list[Group]:
    Lyr = cfg.n_layers
    if cfg.block_kind == "rwkv6":
        return [Group((("rwkv6", "rwkv_ffn"),), Lyr)]
    if cfg.block_kind == "mamba2":
        per = cfg.shared_attn_period or Lyr
        kinds = tuple((("mamba", None),) * per) + ((("shared_gqa", "mlp"),) if cfg.shared_attn_period else ())
        n_full, rem = divmod(Lyr, per)
        groups = [Group(kinds, n_full)]
        if rem:
            groups.append(Group((("mamba", None),) * rem, 1))
        return groups
    # attention families
    ffn = "moe" if cfg.moe else "mlp"
    mixer = "mla" if cfg.attn_kind == "mla" else None
    groups: list[Group] = []
    if cfg.moe and cfg.first_dense_layers:
        mk = mixer or "gqa_g"
        groups.append(Group(((mk, "mlp"),), cfg.first_dense_layers))
        Lyr -= cfg.first_dense_layers
    if mixer == "mla":
        groups.append(Group((("mla", ffn),), Lyr))
        return groups
    period = tuple((("gqa_l" if c == "l" else "gqa_g"), "mlp") for c in cfg.attn_pattern)
    n_full, rem = divmod(Lyr, len(period))
    if n_full:
        groups.append(Group(period, n_full))
    if rem:
        groups.append(Group(period[:rem], 1))
    return groups


def enc_groups(cfg) -> list[Group]:
    return [Group((("enc_attn", "mlp"),), cfg.n_enc_layers)]


def dec_groups(cfg) -> list[Group]:
    return [Group((("dec_attn", "mlp"),), cfg.n_layers)]


# ---------------------------------------------------------------------------
# per-layer defs
# ---------------------------------------------------------------------------

def _norm_defs(cfg):
    return L.rmsnorm_defs(cfg.d_model) if cfg.norm_kind == "rms" else L.layernorm_defs(cfg.d_model)


def _norm_apply(cfg, p, x):
    return L.rmsnorm_apply(p, x) if cfg.norm_kind == "rms" else L.layernorm_apply(p, x)


def mlp_defs(cfg) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    d = {
        "w1": ParamDef((D, F), ("embed", "mlp"), init="scaled"),
        "w2": ParamDef((F, D), ("mlp", "embed"), init="scaled"),
    }
    if cfg.mlp_kind in ("swiglu", "geglu"):
        d["w3"] = ParamDef((D, F), ("embed", "mlp"), init="scaled")
    return d


def mlp_apply(cfg, p, x):
    h = x @ p["w1"].astype(x.dtype)
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(h) * (x @ p["w3"].astype(x.dtype))
    elif cfg.mlp_kind == "geglu":
        h = jax.nn.gelu(h, approximate=True) * (x @ p["w3"].astype(x.dtype))
    else:
        h = jax.nn.gelu(h, approximate=True)
    return h @ p["w2"].astype(x.dtype)


def layer_defs(cfg, kind) -> dict:
    mixer, ffn = kind
    d: dict = {"ln1": _norm_defs(cfg)}
    if mixer in ("gqa_g", "gqa_l", "enc_attn", "dec_attn"):
        d["attn"] = L.gqa_defs(cfg)
    elif mixer == "mla":
        d["attn"] = MLA.mla_defs(cfg)
    elif mixer == "mamba":
        d["mixer"] = SSM.mamba2_defs(cfg)
    elif mixer == "rwkv6":
        d["mixer"] = RWKV.rwkv6_defs(cfg)["time"]
    elif mixer == "shared_gqa":
        return {}  # all params live at model level (single shared copy)
    if mixer == "dec_attn":
        d["lnx"] = _norm_defs(cfg)
        d["cross"] = L.gqa_defs(cfg)
    if ffn == "mlp":
        d["ln2"] = _norm_defs(cfg)
        d["mlp"] = mlp_defs(cfg)
    elif ffn == "moe":
        d["ln2"] = _norm_defs(cfg)
        d["moe"] = MOE.moe_defs(cfg)
    elif ffn == "rwkv_ffn":
        d["ln2"] = _norm_defs(cfg)
        d["ffn"] = RWKV.rwkv6_defs(cfg)["channel"]
    if cfg.post_norm and mixer != "shared_gqa":
        d["ln1_post"] = _norm_defs(cfg)
        if ffn in ("mlp", "moe"):
            d["ln2_post"] = _norm_defs(cfg)
    return d


def _stack_defs(defs, n: int):
    """Prepend a scanned 'layers' dim of size n to every ParamDef."""
    return jax.tree.map(
        lambda d: ParamDef((n, *d.shape), ("layers", *d.axes), init=d.init,
                           dtype=d.dtype, scale=d.scale),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


# ---------------------------------------------------------------------------
# cache defs
# ---------------------------------------------------------------------------

def _cache_defs_for(cfg, kind, batch: int, max_len: int) -> dict | None:
    mixer, _ = kind
    Hkv, dh = cfg.n_kv_heads, cfg.head_dim
    if mixer in ("gqa_g", "dec_attn", "shared_gqa"):
        kv_dt = jnp.int8 if cfg.kv_quant_int8 else jnp.bfloat16
        d = {
            "k": ParamDef((batch, max_len, Hkv, dh), ("batch", "seq", "kv_heads", None),
                          init="zeros", dtype=kv_dt),
            "v": ParamDef((batch, max_len, Hkv, dh), ("batch", "seq", "kv_heads", None),
                          init="zeros", dtype=kv_dt),
        }
        if cfg.kv_quant_int8:
            d["k_s"] = ParamDef((batch, max_len, Hkv), ("batch", "seq", "kv_heads"),
                                init="zeros", dtype=jnp.float32)
            d["v_s"] = ParamDef((batch, max_len, Hkv), ("batch", "seq", "kv_heads"),
                                init="zeros", dtype=jnp.float32)
        if mixer == "dec_attn":
            el = cfg.enc_len
            d["xk"] = ParamDef((batch, el, Hkv, dh), ("batch", None, "kv_heads", None),
                               init="zeros", dtype=jnp.bfloat16)
            d["xv"] = ParamDef((batch, el, Hkv, dh), ("batch", None, "kv_heads", None),
                               init="zeros", dtype=jnp.bfloat16)
        return d
    if mixer == "gqa_l":
        W = min(cfg.window or max_len, max_len)
        return {
            "k": ParamDef((batch, W, Hkv, dh), ("batch", None, "kv_heads", None),
                          init="zeros", dtype=jnp.bfloat16),
            "v": ParamDef((batch, W, Hkv, dh), ("batch", None, "kv_heads", None),
                          init="zeros", dtype=jnp.bfloat16),
        }
    if mixer == "mla":
        return {
            "c": ParamDef((batch, max_len, cfg.kv_lora_rank), ("batch", "seq", "mla_latent"),
                          init="zeros", dtype=jnp.bfloat16),
            "pe": ParamDef((batch, max_len, cfg.qk_rope_head_dim), ("batch", "seq", None),
                           init="zeros", dtype=jnp.bfloat16),
        }
    if mixer == "mamba":
        return SSM.mamba2_state_defs(cfg, batch)
    if mixer == "rwkv6":
        return RWKV.rwkv6_state_defs(cfg, batch)
    if mixer == "enc_attn":
        return None
    raise ValueError(mixer)


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _gqa_attend(cfg, p, x, *, local: bool, positions, mode, cache, prefix_len,
                softcap, theta):
    """Returns (out, new_cache)."""
    B, S, D = x.shape
    q, k, v = L.gqa_project(p, x, cfg, positions, theta)
    W = cfg.window
    if mode == "decode":
        pos = positions[:, 0]  # (B,) all equal
        pos0 = pos[0]
        if local:
            Wr = cache["k"].shape[1]
            slot = pos0 % Wr
            kc = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
            vc = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
            o = _ring_decode(q, kc, vc, pos0, Wr, softcap)
            new_cache = {"k": kc, "v": vc}
        elif cfg.kv_quant_int8:
            kq, ks = L.quantize_kv(k)
            vq, vs = L.quantize_kv(v)
            kc = lax.dynamic_update_slice_in_dim(cache["k"], kq, pos0, axis=1)
            vc = lax.dynamic_update_slice_in_dim(cache["v"], vq, pos0, axis=1)
            ksc = lax.dynamic_update_slice_in_dim(cache["k_s"], ks, pos0, axis=1)
            vsc = lax.dynamic_update_slice_in_dim(cache["v_s"], vs, pos0, axis=1)
            o = L.decode_attention_quant(q, kc, vc, ksc, vsc, length=pos0 + 1,
                                         softcap=softcap)
            new_cache = {"k": kc, "v": vc, "k_s": ksc, "v_s": vsc}
        else:
            kc = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos0, axis=1)
            vc = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos0, axis=1)
            o = L.decode_attention(q, kc, vc, length=pos0 + 1, softcap=softcap)
            new_cache = {"k": kc, "v": vc}
        return L.gqa_out(p, o, x.dtype), new_cache

    # train / prefill
    if local and W is not None and S > W:
        o = L.local_attention(q, k, v, window=W, softcap=softcap)
    elif S <= 1024:
        o = L.dense_attention(q, k, v, causal=True, window=W if local else None,
                              softcap=softcap, prefix_len=prefix_len)
    elif cfg.flash_attention and softcap is None and prefix_len == 0:
        from repro.models.flash import flash_attention
        o = flash_attention(q, k, v, True, cfg.block_q, cfg.block_k)
    else:
        o = L.blockwise_attention(q, k, v, causal=True, softcap=softcap,
                                  prefix_len=prefix_len, block_q=cfg.block_q,
                                  block_k=cfg.block_k)
    new_cache = None
    if mode == "prefill" and cache is not None:
        Wr = cache["k"].shape[1]
        if local:
            kc, vc = _ring_fill(cache, k, v, S, Wr)
            new_cache = {"k": kc, "v": vc}
        elif cfg.kv_quant_int8:
            kq, ks = L.quantize_kv(k)
            vq, vs = L.quantize_kv(v)
            new_cache = {
                "k": lax.dynamic_update_slice_in_dim(cache["k"], kq, 0, axis=1),
                "v": lax.dynamic_update_slice_in_dim(cache["v"], vq, 0, axis=1),
                "k_s": lax.dynamic_update_slice_in_dim(cache["k_s"], ks, 0, axis=1),
                "v_s": lax.dynamic_update_slice_in_dim(cache["v_s"], vs, 0, axis=1),
            }
        else:
            kc = lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
            vc = lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
            new_cache = {"k": kc, "v": vc}
    return L.gqa_out(p, o, x.dtype), new_cache


def _ring_fill(cache, k, v, S, Wr):
    """Store last Wr tokens of (k, v) in ring order (slot = pos % Wr)."""
    take = min(S, Wr)
    k_t = k[:, S - take:]
    v_t = v[:, S - take:]
    pos = jnp.arange(S - take, S) % Wr
    kc = cache["k"].at[:, pos].set(k_t.astype(cache["k"].dtype))
    vc = cache["v"].at[:, pos].set(v_t.astype(cache["v"].dtype))
    return kc, vc


def _ring_decode(q, kc, vc, pos, Wr, softcap):
    """Decode attention over a ring cache: slot j holds abs position
    p = pos - ((pos - j) mod Wr); valid iff p >= 0 (softmax is order-free)."""
    j = jnp.arange(Wr)
    abs_pos = pos - jnp.mod(pos - j, Wr)
    B, _, Hq, Dh = q.shape
    Hkv = kc.shape[2]
    G = Hq // Hkv
    qr = q.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, kc,
                   preferred_element_type=jnp.float32) / math.sqrt(Dh)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = (abs_pos >= 0)[None, None, None, :]
    s = jnp.where(mask, s, L.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(vc.dtype), vc)
    return o.reshape(B, 1, Hq, Dh)


def _apply_layer(cfg, kind, p, x, *, mesh, positions, mode, cache, prefix_len,
                 enc_out=None, shared_params=None):
    """One sub-layer.  Returns (x, new_cache)."""
    mixer, ffn = kind
    new_cache = cache

    if mixer == "shared_gqa":
        p = shared_params  # single copy, reused every period
    theta = cfg.rope_theta
    if mixer == "gqa_l" and cfg.rope_theta_local is not None:
        theta = cfg.rope_theta_local

    if mixer in ("gqa_g", "gqa_l", "shared_gqa"):
        h = _norm_apply(cfg, p["ln1"], x)
        o, nc = _gqa_attend(cfg, p["attn"], h, local=(mixer == "gqa_l"),
                            positions=positions, mode=mode, cache=cache,
                            prefix_len=prefix_len, softcap=cfg.logit_softcap,
                            theta=theta)
        if cfg.post_norm and mixer != "shared_gqa":
            o = _norm_apply(cfg, p["ln1_post"], o)
        x = x + o
        new_cache = nc
    elif mixer == "enc_attn":
        h = _norm_apply(cfg, p["ln1"], x)
        q, k, v = _proj_nopos(p["attn"], h)
        o = (L.dense_attention(q, k, v, causal=False) if h.shape[1] <= 1024 else
             L.blockwise_attention(q, k, v, causal=False, block_q=cfg.block_q,
                                   block_k=cfg.block_k))
        x = x + L.gqa_out(p["attn"], o, x.dtype)
        new_cache = None
    elif mixer == "dec_attn":
        h = _norm_apply(cfg, p["ln1"], x)
        o, nc_self = _gqa_attend(cfg, p["attn"], h, local=False, positions=positions,
                                 mode=mode, cache=None if cache is None else
                                 {"k": cache["k"], "v": cache["v"]},
                                 prefix_len=0, softcap=None, theta=cfg.rope_theta)
        x = x + o
        # cross attention over encoder memory
        h = _norm_apply(cfg, p["lnx"], x)
        q = jnp.einsum("bsd,dhe->bshe", h, p["cross"]["wq"].astype(h.dtype))
        if mode == "train" or (mode == "prefill" and enc_out is not None):
            xk = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross"]["wk"].astype(h.dtype))
            xv = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross"]["wv"].astype(h.dtype))
        else:
            xk, xv = cache["xk"].astype(h.dtype), cache["xv"].astype(h.dtype)
        o = L.dense_attention(q, xk, xv, causal=False)
        x = x + L.gqa_out(p["cross"], o, x.dtype)
        if cache is not None:
            new_cache = dict(nc_self or {},
                             xk=xk.astype(cache["xk"].dtype),
                             xv=xv.astype(cache["xv"].dtype))
        else:
            new_cache = None
    elif mixer == "mla":
        h = _norm_apply(cfg, p["ln1"], x)
        if mode == "decode":
            pos0 = positions[0, 0]
            c_new, pe_new = MLA.mla_prefill_cache(p["attn"], h, cfg, positions)
            cc = lax.dynamic_update_slice_in_dim(cache["c"], c_new.astype(cache["c"].dtype), pos0, axis=1)
            pc = lax.dynamic_update_slice_in_dim(cache["pe"], pe_new.astype(cache["pe"].dtype), pos0, axis=1)
            o = MLA.mla_decode(p["attn"], h, cfg, cc, pc, length=pos0)
            new_cache = {"c": cc, "pe": pc}
        else:
            o = MLA.mla_train(p["attn"], h, cfg, positions, prefix_len=prefix_len)
            if mode == "prefill" and cache is not None:
                c_new, pe_new = MLA.mla_prefill_cache(p["attn"], h, cfg, positions)
                cc = lax.dynamic_update_slice_in_dim(cache["c"], c_new.astype(cache["c"].dtype), 0, axis=1)
                pc = lax.dynamic_update_slice_in_dim(cache["pe"], pe_new.astype(cache["pe"].dtype), 0, axis=1)
                new_cache = {"c": cc, "pe": pc}
            else:
                new_cache = None
        x = x + o
    elif mixer == "mamba":
        h = _norm_apply(cfg, p["ln1"], x)
        o, st = SSM.mamba2_apply(p["mixer"], h, cfg, cache)
        x = x + o
        new_cache = st if cache is not None else None
    elif mixer == "rwkv6":
        h = _norm_apply(cfg, p["ln1"], x)
        o, st = RWKV.rwkv6_time_mix(p["mixer"], h, cfg,
                                    None if cache is None else cache["time"])
        x = x + o
        if ffn == "rwkv_ffn":
            h = _norm_apply(cfg, p["ln2"], x)
            o2, st2 = RWKV.rwkv6_channel_mix(p["ffn"], h, cfg,
                                             None if cache is None else cache["channel"])
            x = x + o2
            new_cache = {"time": st, "channel": st2} if cache is not None else None
        return x, new_cache
    else:
        raise ValueError(mixer)

    # ffn half
    if ffn == "mlp":
        h = _norm_apply(cfg, p["ln2"], x)
        o = mlp_apply(cfg, p["mlp"], h)
        if cfg.post_norm and mixer != "shared_gqa":
            o = _norm_apply(cfg, p["ln2_post"], o)
        x = x + o
    elif ffn == "moe":
        h = _norm_apply(cfg, p["ln2"], x)
        B, S, D = h.shape
        o = MOE.moe_apply(p["moe"], h.reshape(B * S, D), cfg, mesh).reshape(B, S, D)
        x = x + o
    return x, new_cache


def _proj_nopos(p, x):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(x.dtype))
    return q, k, v
