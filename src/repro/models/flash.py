"""Memory-efficient causal attention with a flash-style custom VJP.

The pure-JAX blockwise attention (layers.blockwise_attention) lets JAX's
autodiff save the per-block probabilities for the backward pass — the
roofline instrument measures ~0.5 MB/token/layer of HBM traffic for those
stacked (block_q x block_k) tensors, which dominates the train-cell memory
term (EXPERIMENTS.md §Perf).

This version stores only (out, m, l) per row — the softmax statistics —
and *recomputes* probabilities blockwise inside the custom backward
(Dao et al., FlashAttention backward), trading ~30% extra attention FLOPs
(compute term is far from dominant) for eliminating the S^2 residual
traffic.  Enabled per-config via ModelConfig.flash_attention.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _mask(qi, ki, bq, bk, causal):
    qpos = qi * bq + jnp.arange(bq)
    kpos = ki * bk + jnp.arange(bk)
    if causal:
        return kpos[None, :] <= qpos[:, None]
    return jnp.ones((bq, bk), bool)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 512,
                    block_k: int = 512):
    """q: (B,S,Hq,D); k/v: (B,S,Hkv,D) -> (B,S,Hq,D)."""
    out, _, _ = _fwd(q, k, v, causal, block_q, block_k)
    return out


def _fwd(q, k, v, causal, bq, bk):
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(D)
    qb = jnp.moveaxis(q.reshape(B, nq, bq, Hkv, G, D), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, bk, Hkv, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bk, Hkv, D), 1, 0)

    def qblock(qi, q_i):
        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_j, v_j = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            s = jnp.where(_mask(qi, ki, bq, bk, causal)[None, None, None],
                          s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_j.dtype), v_j).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, bq, D), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  (jnp.arange(nk), kb, vb))
        o = (acc / jnp.maximum(l, 1e-30)[..., None])
        return o, m, l  # o: (B,Hkv,G,bq,D)

    outs, ms, ls = lax.map(lambda i: qblock(i, qb[i]), jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1)                      # (B,nq,Hkv,G,bq,D)
    out = jnp.moveaxis(out, (2, 3), (3, 4)).reshape(B, S, Hq, D).astype(q.dtype)
    return out, ms, ls  # ms/ls: (nq,B,Hkv,G,bq)


def _fwd_vjp(q, k, v, causal, bq, bk):
    out, ms, ls = _fwd(q, k, v, causal, bq, bk)
    return out, (q, k, v, out, ms, ls)


def _bwd_vjp(causal, bq, bk, res, dout):
    q, k, v, out, ms, ls = res
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(D)

    qb = jnp.moveaxis(q.reshape(B, nq, bq, Hkv, G, D), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, bk, Hkv, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bk, Hkv, D), 1, 0)
    ob = jnp.moveaxis(out.reshape(B, nq, bq, Hkv, G, D), 1, 0)
    dob = jnp.moveaxis(dout.reshape(B, nq, bq, Hkv, G, D), 1, 0)

    # delta = rowsum(do * o)  (B,Hkv,G,bq) per q block
    def qblock(qi):
        q_i = qb[qi]
        do_i = jnp.moveaxis(dob[qi], 1, 3).astype(jnp.float32)  # B,Hkv,G,bq,D
        o_i = jnp.moveaxis(ob[qi], 1, 3).astype(jnp.float32)
        delta = (do_i * o_i).sum(-1)                    # (B,Hkv,G,bq)
        m_i, l_i = ms[qi], ls[qi]

        def kv_step(dq_acc, inp):
            ki, k_j, v_j = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            s = jnp.where(_mask(qi, ki, bq, bk, causal)[None, None, None],
                          s, NEG_INF)
            p = jnp.exp(s - m_i[..., None]) / jnp.maximum(l_i, 1e-30)[..., None]
            dp = jnp.einsum("bhgqd,bkhd->bhgqk", do_i, v_j.astype(jnp.float32))
            ds = p * (dp - delta[..., None]) * scale
            dv_j = jnp.einsum("bhgqk,bhgqd->bkhd", p, do_i)
            dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                              q_i.astype(jnp.float32))
            dq_new = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                                         k_j.astype(jnp.float32))
            return dq_new, (dk_j, dv_j)

        dq0 = jnp.zeros((B, bq, Hkv, G, D), jnp.float32)
        dq_i, (dks, dvs) = lax.scan(kv_step, dq0, (jnp.arange(nk), kb, vb))
        return dq_i, dks, dvs

    dqs, dks, dvs = lax.map(qblock, jnp.arange(nq))
    # dq: (nq,B,bq,Hkv,G,D) -> (B,S,Hq,D)
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, S, Hkv, G, D).reshape(B, S, Hq, D)
    # dk/dv: (nq,nk,B,bk,Hkv,D) summed over q blocks
    dk = jnp.moveaxis(dks.sum(0), 0, 1).reshape(B, S, Hkv, D)
    dv = jnp.moveaxis(dvs.sum(0), 0, 1).reshape(B, S, Hkv, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_fwd_vjp, _bwd_vjp)
