"""Public Model API: init / loss / prefill / decode_step / input_specs.

One class serves the whole zoo; behaviour is driven entirely by ModelConfig.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.common.pytree import ParamDef, abstract, materialize, specs_of
from repro.common.sharding import MeshRules
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import moe as MOE
from repro.models import transformer as T
from repro.models.layers import sinusoidal_at, sinusoidal_pos
from repro.models.transformer import Group, _apply_layer, _norm_apply, _norm_defs


def _group_defs(cfg, g: Group) -> dict:
    d = {}
    for j, kind in enumerate(g.kinds):
        d[f"l{j}"] = T._stack_defs(T.layer_defs(cfg, kind), g.n)
    return d


class Model:
    def __init__(self, cfg: ModelConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.groups = T.build_groups(cfg)
        self.compute_dtype = jnp.bfloat16
        self.param_dtype = jnp.dtype(cfg.param_dtype)

    # ------------------------------------------------------------------ defs
    def param_defs(self) -> dict:
        cfg = self.cfg
        pd = jnp.dtype(cfg.param_dtype)

        def dtyped(tree):
            return jax.tree.map(
                lambda d: ParamDef(d.shape, d.axes, init=d.init, dtype=pd, scale=d.scale),
                tree, is_leaf=lambda x: isinstance(x, ParamDef))

        d: dict = {
            "embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                              init="normal", dtype=pd),
            "final_norm": _norm_defs(cfg),
            "groups": [_group_defs(cfg, g) for g in self.groups],
        }
        if not cfg.tie_embeddings:
            d["lm_head"] = ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                                    init="scaled", dtype=pd)
        if cfg.shared_attn_period:
            d["shared_block"] = T.layer_defs(cfg, ("gqa_g", "mlp"))
        if cfg.enc_dec:
            d["enc_groups"] = [_group_defs(cfg, g) for g in T.enc_groups(cfg)]
            d["enc_norm"] = _norm_defs(cfg)
        return dtyped(d)

    def init(self, key: jax.Array):
        return materialize(self.param_defs(), key)

    def param_specs(self, rules: MeshRules | None = None):
        rules = rules or self.rules()
        return specs_of(self.param_defs(), rules)

    def rules(self) -> MeshRules:
        assert self.mesh is not None
        overrides = MOE.moe_param_overrides(self.cfg) or {}
        return MeshRules.create(self.mesh, overrides)

    # -------------------------------------------------------------- plumbing
    def _embed(self, params, tokens):
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.compute_dtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), self.compute_dtype)
        return x

    def _logits(self, params, x):
        cfg = self.cfg
        w = (params["embed"] if cfg.tie_embeddings else params["lm_head"].T)
        logits = jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype),
                            preferred_element_type=jnp.float32)
        if cfg.tie_embeddings and cfg.embed_scale:
            logits = logits / math.sqrt(cfg.d_model)
        if cfg.final_softcap is not None:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits

    def _run_groups(self, pgroups, x, *, mode, caches, positions, prefix_len,
                    enc_out=None, shared_params=None, group_list=None):
        cfg, mesh = self.cfg, self.mesh
        group_list = group_list or self.groups
        new_caches = []
        for gi, g in enumerate(group_list):
            gp = pgroups[gi]
            gc = None if caches is None else caches[gi]

            def body(xc, slices, g=g):
                pslice, cslice = slices
                ncs = {}
                for j, kind in enumerate(g.kinds):
                    c_j = None if cslice is None else cslice.get(f"l{j}")
                    xc, nc = _apply_layer(
                        cfg, kind, pslice[f"l{j}"], xc, mesh=mesh,
                        positions=positions, mode=mode, cache=c_j,
                        prefix_len=prefix_len, enc_out=enc_out,
                        shared_params=shared_params)
                    if cslice is not None:
                        ncs[f"l{j}"] = nc if nc is not None else c_j
                return xc, ncs

            if mode == "train":
                def fbody_(xc, ps, g=g):
                    xc, nc = body(xc, (ps, None), g=g)
                    if cfg.seq_parallel and self.mesh is not None:
                        from jax.sharding import PartitionSpec as P
                        batch_axes = tuple(a for a in self.mesh.axis_names
                                           if a in ("pod", "data"))
                        xc = lax.with_sharding_constraint(
                            xc, P(batch_axes, "model", None))
                    return xc, nc
                fbody = jax.checkpoint(fbody_)
                x, _ = lax.scan(fbody, x, gp)
                new_caches.append(None)
            else:
                x, nc = lax.scan(lambda xc, s: body(xc, s), x, (gp, gc))
                new_caches.append(nc)
        return x, new_caches

    # ------------------------------------------------------------------ train
    def loss(self, params, batch):
        """Next-token CE.  batch: tokens (B,S) [+ img/frames]."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S_text = tokens.shape
        x = self._embed(params, tokens)
        prefix_len = 0
        enc_out = None

        if cfg.vlm_prefix_len:
            img = batch["img"].astype(self.compute_dtype)  # (B, P, D)
            x = jnp.concatenate([img, x], axis=1)
            prefix_len = cfg.vlm_prefix_len
        if cfg.enc_dec:
            enc_out = self._encode(params, batch["frames"])
        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.enc_dec:
            x = x + sinusoidal_pos(S, cfg.d_model).astype(x.dtype)[None]

        shared = params.get("shared_block")
        x, _ = self._run_groups(params["groups"], x, mode="train", caches=None,
                                positions=positions, prefix_len=prefix_len,
                                enc_out=enc_out, shared_params=shared)
        x = _norm_apply(cfg, params["final_norm"], x)
        logits = self._logits(params, x)
        if cfg.vlm_prefix_len:
            logits = logits[:, cfg.vlm_prefix_len:]
        # next-token prediction over text tokens
        tgt = tokens[:, 1:]
        lg = logits[:, :-1].astype(jnp.float32)
        mask = batch.get("loss_mask")
        mask = jnp.ones_like(tgt, jnp.float32) if mask is None else mask[:, 1:].astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        picked = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
        nll = (lse - picked) * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)

    def _encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(self.compute_dtype)
        x = x + sinusoidal_pos(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        B = x.shape[0]
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], (B, x.shape[1]))
        x, _ = self._run_groups(params["enc_groups"], x, mode="train", caches=None,
                                positions=positions, prefix_len=0,
                                group_list=T.enc_groups(cfg))
        return _norm_apply(cfg, params["enc_norm"], x)

    # ------------------------------------------------------------------ serve
    def cache_defs(self, batch: int, max_len: int):
        cfg = self.cfg
        out = []
        for g in self.groups:
            gd = {}
            for j, kind in enumerate(g.kinds):
                cd = T._cache_defs_for(cfg, kind, batch, max_len)
                if cd is not None:
                    gd[f"l{j}"] = T._stack_defs(cd, g.n)
                else:
                    gd[f"l{j}"] = {}
            out.append(gd)
        return {"layers": out, "pos": ParamDef((), (), init="zeros", dtype=jnp.int32)}

    def init_cache(self, batch: int, max_len: int):
        return materialize(self.cache_defs(batch, max_len), jax.random.PRNGKey(0))

    def prefill(self, params, batch, max_len: int | None = None):
        """Forward over the prompt, building the decode cache.

        Returns (last_logits (B,V), cache)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S_text = tokens.shape
        x = self._embed(params, tokens)
        prefix_len = 0
        enc_out = None
        if cfg.vlm_prefix_len:
            x = jnp.concatenate([batch["img"].astype(self.compute_dtype), x], axis=1)
            prefix_len = cfg.vlm_prefix_len
        if cfg.enc_dec:
            enc_out = self._encode(params, batch["frames"])
        S = x.shape[1]
        max_len = max_len or S
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.enc_dec:
            x = x + sinusoidal_pos(S, cfg.d_model).astype(x.dtype)[None]
        cache = self.init_cache(B, max_len)
        shared = params.get("shared_block")
        x, ncaches = self._run_groups(params["groups"], x, mode="prefill",
                                      caches=cache["layers"], positions=positions,
                                      prefix_len=prefix_len, enc_out=enc_out,
                                      shared_params=shared)
        x = _norm_apply(cfg, params["final_norm"], x)
        logits = self._logits(params, x[:, -1:])[:, 0]
        return logits, {"layers": ncaches, "pos": jnp.asarray(S, jnp.int32)}

    def decode_step(self, params, cache, tokens):
        """tokens (B,1) at position cache["pos"].  Returns (logits, cache)."""
        cfg = self.cfg
        B = tokens.shape[0]
        pos = cache["pos"]
        x = self._embed(params, tokens)
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        if cfg.enc_dec:
            x = x + sinusoidal_at(pos, cfg.d_model).astype(x.dtype)[None, None]
        shared = params.get("shared_block")
        x, ncaches = self._run_groups(params["groups"], x, mode="decode",
                                      caches=cache["layers"], positions=positions,
                                      prefix_len=0, shared_params=shared)
        x = _norm_apply(cfg, params["final_norm"], x)
        logits = self._logits(params, x)[:, 0]
        return logits, {"layers": ncaches, "pos": pos + 1}

    # ------------------------------------------------------------- dry-run IO
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        bf16 = jnp.bfloat16
        if shape.kind in ("train", "prefill"):
            S_text = S - cfg.vlm_prefix_len if cfg.vlm_prefix_len else S
            d = {"tokens": jax.ShapeDtypeStruct((B, S_text), i32)}
            if cfg.vlm_prefix_len:
                d["img"] = jax.ShapeDtypeStruct((B, cfg.vlm_prefix_len, cfg.d_model), bf16)
            if cfg.enc_dec:
                d["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16)
            return d
        # decode: one new token over a seq_len cache
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "cache": abstract(self.cache_defs(B, S)),
        }

    def batch_pspecs(self, shape: ShapeConfig, rules: MeshRules | None = None):
        rules = rules or self.rules()
        B = shape.global_batch
        specs = self.input_specs(shape)

        def tok_spec(name):
            s = specs[name] if name in specs else None
            return rules.pspec(("batch",) + (None,) * (len(s.shape) - 1), s.shape)

        if shape.kind in ("train", "prefill"):
            d = {"tokens": tok_spec("tokens")}
            if self.cfg.vlm_prefix_len:
                d["img"] = tok_spec("img")
            if self.cfg.enc_dec:
                d["frames"] = tok_spec("frames")
            return d
        cache_rules = self.cache_rules(shape)
        return {
            "tokens": cache_rules.pspec(("batch", None), (B, 1)),
            "cache": specs_of(self.cache_defs(B, shape.seq_len), cache_rules),
        }

    def cache_rules(self, shape: ShapeConfig) -> MeshRules:
        overrides = dict(MOE.moe_param_overrides(self.cfg) or {})
        if shape.cache_shard == "seq":
            overrides.update({"batch": (), "seq": ("pod", "data")})
        elif self.cfg.decode_seq_shard:
            # batch over (pod, data) AND cache sequence over "model":
            # decode attention's softmax reductions over the sharded seq
            # axis lower to small all-reduces (sequence-parallel decode)
            overrides.update({"seq": ("model",), "kv_heads": ()})
        else:
            overrides.update({"seq": ()})
        return MeshRules.create(self.mesh, overrides)
