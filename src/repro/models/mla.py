"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2405.04434 §2.1).

KV is compressed to a ``kv_lora_rank`` latent + a small shared RoPE key;
the decode cache stores only (c_kv, k_rope) per token — 576 dims instead of
2 * H * head_dim.  Decode uses the *absorbed* form (W_UK folded into the
query, W_UV applied to the latent context) so attention runs directly on
the latent cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common.pytree import ParamDef
from repro.models.layers import (
    apply_rope,
    blockwise_attention,
    dense_attention,
    rmsnorm_apply,
    NEG_INF,
)


def mla_defs(cfg) -> dict:
    D, H = cfg.d_model, cfg.n_heads
    r_kv, d_nope, d_rope, d_v = (cfg.kv_lora_rank, cfg.qk_nope_head_dim,
                                 cfg.qk_rope_head_dim, cfg.v_head_dim)
    d = {
        "wkv_a": ParamDef((D, r_kv + d_rope), ("embed", "mla_latent"), init="scaled"),
        "kv_norm": {"scale": ParamDef((r_kv,), (None,), init="zeros")},
        "wkv_b": ParamDef((r_kv, H, d_nope + d_v), (None, "heads", None), init="scaled"),
        "wo": ParamDef((H, d_v, D), ("heads", None, "embed"), init="scaled"),
    }
    if cfg.q_lora_rank:
        r_q = cfg.q_lora_rank
        d["wq_a"] = ParamDef((D, r_q), ("embed", None), init="scaled")
        d["q_norm"] = {"scale": ParamDef((r_q,), (None,), init="zeros")}
        d["wq_b"] = ParamDef((r_q, H, d_nope + d_rope), (None, "heads", None), init="scaled")
    else:
        d["wq"] = ParamDef((D, H, d_nope + d_rope), ("embed", "heads", None), init="scaled")
    return d


def _queries(p, x, cfg, positions):
    d_nope, d_rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        q_lat = x @ p["wq_a"].astype(x.dtype)
        q_lat = rmsnorm_apply(p["q_norm"], q_lat)
        q = jnp.einsum("bsr,rhe->bshe", q_lat, p["wq_b"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    q_nope, q_pe = q[..., :d_nope], q[..., d_nope:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def _latent_kv(p, x, cfg, positions):
    """Returns (c_kv normalized, k_pe roped) — exactly what the cache stores."""
    r_kv, d_rope = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv_a = x @ p["wkv_a"].astype(x.dtype)
    c_kv, k_pe = kv_a[..., :r_kv], kv_a[..., r_kv:]
    c_kv = rmsnorm_apply(p["kv_norm"], c_kv)
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_pe


def mla_train(p, x, cfg, positions, *, prefix_len: int = 0):
    """Non-absorbed form for train/prefill: materialize per-head K/V and run
    blockwise causal attention."""
    B, S, D = x.shape
    H = cfg.n_heads
    d_nope, d_rope, d_v = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_pe = _queries(p, x, cfg, positions)
    c_kv, k_pe = _latent_kv(p, x, cfg, positions)
    kv = jnp.einsum("bsr,rhe->bshe", c_kv, p["wkv_b"].astype(x.dtype))
    k_nope, v = kv[..., :d_nope], kv[..., d_nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, d_rope))],
                        axis=-1)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    # pad V up to the qk head dim so one attention kernel serves both
    if d_v < d_nope + d_rope:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, d_nope + d_rope - d_v)))
    if S <= 2048:
        o = dense_attention(q, k, v, causal=True, prefix_len=prefix_len)
    elif cfg.flash_attention and prefix_len == 0:
        from repro.models.flash import flash_attention
        o = flash_attention(q, k, v, True, cfg.block_q, cfg.block_k)
    else:
        o = blockwise_attention(q, k, v, causal=True, prefix_len=prefix_len,
                                block_q=cfg.block_q, block_k=cfg.block_k)
    o = o[..., :d_v]
    return jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(x.dtype)).astype(x.dtype)


def mla_prefill_cache(p, x, cfg, positions):
    """(c_kv, k_pe) to stash in the decode cache."""
    return _latent_kv(p, x, cfg, positions)


def mla_decode(p, x, cfg, c_cache, pe_cache, *, length):
    """Absorbed decode: x (B,1,D); cache c (B,Smax,r_kv), pe (B,Smax,d_rope).

    score_h(t) = q_nope_h . (W_UK_h c_t) + q_pe_h . k_pe_t
               = (W_UK_h^T q_nope_h) . c_t + q_pe_h . k_pe_t
    ctx_h = W_UV_h^T (sum_t p_t c_t)
    """
    B = x.shape[0]
    d_nope, d_rope, d_v = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = jnp.full((B, 1), length, jnp.int32)
    q_nope, q_pe = _queries(p, x, cfg, positions)  # (B,1,H,*)
    w_uk = p["wkv_b"][..., :d_nope].astype(x.dtype)   # (r, H, d_nope)
    w_uv = p["wkv_b"][..., d_nope:].astype(x.dtype)   # (r, H, d_v)
    q_eff = jnp.einsum("bhe,rhe->bhr", q_nope[:, 0], w_uk)  # (B,H,r)
    scale = 1.0 / math.sqrt(d_nope + d_rope)
    s = (jnp.einsum("bhr,bkr->bhk", q_eff, c_cache.astype(x.dtype),
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhe,bke->bhk", q_pe[:, 0], pe_cache.astype(x.dtype),
                      preferred_element_type=jnp.float32)) * scale
    kpos = jnp.arange(c_cache.shape[1])[None, None, :]
    s = jnp.where(kpos <= length, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhk,bkr->bhr", pr.astype(x.dtype), c_cache.astype(x.dtype))
    o = jnp.einsum("bhr,rhe->bhe", ctx, w_uv)  # (B,H,d_v)
    return jnp.einsum("bhe,hed->bd", o, p["wo"].astype(x.dtype))[:, None]
