"""Mamba-2 block (SSD, arXiv:2405.21060) — chunked scan formulation.

Train path: the "minimal SSD" chunked algorithm — quadratic within a chunk,
linear state passing between chunks (one lax.scan over chunks).
Decode path: single-step recurrence on (conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.pytree import ParamDef
from repro.models.layers import rmsnorm_apply


def mamba2_defs(cfg) -> dict:
    D = cfg.d_model
    Din = cfg.d_inner
    ds = cfg.ssm_state
    nh = cfg.ssm_nheads
    k = cfg.ssm_conv
    conv_dim = Din + 2 * ds  # x + B + C (single group)
    return {
        # in_proj -> [z (Din), x (Din), B (ds), C (ds), dt (nh)]
        "w_in": ParamDef((D, 2 * Din + 2 * ds + nh), ("embed", "mlp"), init="scaled"),
        "conv_w": ParamDef((k, conv_dim), (None, "mlp"), init="scaled"),
        "conv_b": ParamDef((conv_dim,), ("mlp",), init="zeros"),
        "A_log": ParamDef((nh,), (None,), init="zeros"),
        "dt_bias": ParamDef((nh,), (None,), init="zeros"),
        "D_skip": ParamDef((nh,), (None,), init="ones"),
        "out_norm": {"scale": ParamDef((Din,), ("mlp",), init="zeros")},
        "w_out": ParamDef((Din, D), ("mlp", "embed"), init="scaled"),
    }


def _split_proj(cfg, zxbcdt):
    Din, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :Din]
    xbc = zxbcdt[..., Din:Din + Din + 2 * ds]
    dt = zxbcdt[..., Din + Din + 2 * ds:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv1d, kernel k.  xbc: (B,S,C); state: (B,k-1,C)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(xbc[:, : k - 1])
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i].astype(xbc.dtype) for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros((xbc.shape[0], 0, xbc.shape[2]), xbc.dtype)
    return jax.nn.silu(out + b.astype(xbc.dtype)), new_state


def _ssd_chunked(xh, dt, A, Bc, Cc, cfg, init_state=None):
    """SSD chunk scan.

    xh: (B,S,nh,hd); dt: (B,S,nh) (post-softplus); A: (nh,) negative;
    Bc/Cc: (B,S,ds).  Returns (y: (B,S,nh,hd), final_state: (B,nh,hd,ds)).
    """
    Bsz, S, nh, hd = xh.shape
    ds = Bc.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    S_orig = S
    pad = (-S) % Q
    if pad:  # zero-pad: dt=0 on pads => identity state transition
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    C = S // Q

    xq = xh.reshape(Bsz, C, Q, nh, hd)
    dtq = dt.reshape(Bsz, C, Q, nh)
    Bq = Bc.reshape(Bsz, C, Q, ds)
    Cq = Cc.reshape(Bsz, C, Q, ds)

    dA = dtq * A[None, None, None, :]                     # (B,C,Q,nh) negative
    dA_cum = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum

    # within-chunk (quadratic in Q): L[i,j] = exp(dA_cum_i - dA_cum_j) for j<=i
    diff = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]   # (B,C,Q,Q,nh)
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(diff), 0.0)
    sc = jnp.einsum("bcqs,bcks->bcqk", Cq, Bq, preferred_element_type=jnp.float32)
    M = sc[..., None] * L                                  # (B,C,Q,Q,nh)
    y_diag = jnp.einsum("bcqkh,bckhe,bckh->bcqhe", M, xq.astype(jnp.float32),
                        dtq.astype(jnp.float32))

    # chunk states: S_c = sum_j exp(dA_cum_Q - dA_cum_j) * dt_j * B_j x_j^T
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (B,C,Q,nh)
    states = jnp.einsum("bcqh,bcqh,bcqs,bcqhe->bchse",
                        decay_to_end, dtq.astype(jnp.float32), Bq, xq.astype(jnp.float32))
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])             # (B,C,nh)

    def carry_fn(s_prev, inp):
        st, dec = inp                                      # (B,nh,ds,hd), (B,nh)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = (jnp.zeros((Bsz, nh, ds, hd), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, s_prevs = lax.scan(
        carry_fn,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                  # (B,C,nh,ds,hd)

    # inter-chunk contribution: y_off = C_i . exp(dA_cum_i) S_prev
    y_off = jnp.einsum("bcqs,bcqh,bchse->bcqhe",
                       Cq, jnp.exp(dA_cum), s_prevs)
    y = (y_diag + y_off).reshape(Bsz, S, nh, hd)[:, :S_orig]
    return y.astype(xh.dtype), final


def mamba2_apply(p, x, cfg, state=None):
    """x: (B,S,D) -> (B,S,D).  state: None (train) or dict for decode carry.

    Returns (y, new_state).
    """
    Bsz, S, D = x.shape
    nh, hd, ds = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    zxbcdt = x @ p["w_in"].astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc[..., :cfg.d_inner].reshape(Bsz, S, nh, hd)
    Bc = xbc[..., cfg.d_inner:cfg.d_inner + ds]
    Cc = xbc[..., cfg.d_inner + ds:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if S == 1:  # decode: exact single-step recurrence
        s_prev = (jnp.zeros((Bsz, nh, ds, hd), jnp.float32) if state is None
                  else state["ssm"].astype(jnp.float32))
        dA = jnp.exp(dt[:, 0] * A[None, :])                      # (B,nh)
        dBx = jnp.einsum("bh,bs,bhe->bhse", dt[:, 0], Bc[:, 0].astype(jnp.float32),
                         xs[:, 0].astype(jnp.float32))
        s_new = s_prev * dA[..., None, None] + dBx
        y = jnp.einsum("bs,bhse->bhe", Cc[:, 0].astype(jnp.float32), s_new)
        y = y[:, None].astype(x.dtype)
        final = s_new
    else:
        init = None if state is None else state["ssm"]
        y, final = _ssd_chunked(xs, dt, A, Bc, Cc, cfg, init)

    y = y + xs * p["D_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, cfg.d_inner)
    y = rmsnorm_apply(p["out_norm"], y) * jax.nn.silu(z)
    out = y @ p["w_out"].astype(x.dtype)
    new_state = {"conv": new_conv.astype(jnp.float32), "ssm": final}
    return out, new_state


def mamba2_state_defs(cfg, batch: int) -> dict:
    """Abstract decode-state shapes (for cache specs)."""
    k = cfg.ssm_conv
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": ParamDef((batch, k - 1, conv_dim), ("batch", None, "mlp"), init="zeros"),
        "ssm": ParamDef((batch, cfg.ssm_nheads, cfg.ssm_state, cfg.ssm_headdim),
                        ("batch", None, None, None), init="zeros"),
    }
