"""DLRM (Naumov et al., arXiv:1906.00091) with the paper's Table II sizes.

bottom-MLP(dense 1600 -> 1024 x (5+2) -> 64)  ||  64 embedding tables
(dim 64, pooling factor 60, model-parallel over "model")  ->  pairwise dot
interaction -> top-MLP(2048 x (10+2) -> 1) -> CTR logit.

The embedding tables are sharded over the "model" axis (model parallelism);
their per-sample pooled outputs must be exchanged to every data shard —
under pjit this resharding lowers to the All-To-All / All-Gather traffic
the paper studies, and `kernels/embedding_bag` provides the TPU hot-spot
kernel for the multi-hot pooled lookup.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.common.pytree import ParamDef, materialize, specs_of
from repro.common.sharding import MeshRules


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm"
    family: str = "recsys"
    n_dense: int = 1600           # dense features (paper Table II)
    n_tables: int = 64            # sparse features
    emb_dim: int = 64             # embedding dimension
    pooling: int = 60             # multi-hot lookups per table per sample
    rows_per_table: int = 1_000_000
    bot_mlp: tuple[int, ...] = (1024,) * 7    # 5+2 layers @ 1024
    top_mlp: tuple[int, ...] = (2048,) * 12   # 10+2 layers @ 2048
    emb_dtype: str = "bfloat16"   # 16-bit embedding data (paper)
    param_dtype: str = "float32"
    opt_dtype: str = "float32"
    use_pallas_embedding: bool = False


def _mlp_defs(sizes, d_in, d_out, pd):
    defs = {}
    prev = d_in
    for i, h in enumerate(sizes):
        defs[f"w{i}"] = ParamDef((prev, h), ("embed", "mlp"), init="scaled", dtype=pd)
        defs[f"b{i}"] = ParamDef((h,), ("mlp",), init="zeros", dtype=pd)
        prev = h
    defs["w_out"] = ParamDef((prev, d_out), ("mlp", "embed"), init="scaled", dtype=pd)
    defs["b_out"] = ParamDef((d_out,), ("embed",), init="zeros", dtype=pd)
    return defs


def _mlp_apply(p, x, n_hidden):
    for i in range(n_hidden):
        x = jax.nn.relu(x @ p[f"w{i}"].astype(x.dtype) + p[f"b{i}"].astype(x.dtype))
    return x @ p["w_out"].astype(x.dtype) + p["b_out"].astype(x.dtype)


class DLRM:
    def __init__(self, cfg: DLRMConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.compute_dtype = jnp.bfloat16

    def param_defs(self):
        cfg = self.cfg
        pd = jnp.dtype(cfg.param_dtype)
        ed = jnp.dtype(cfg.emb_dtype)
        n_int = cfg.n_tables + 1   # tables + bottom-mlp output
        d_interact = n_int * (n_int - 1) // 2 + cfg.emb_dim
        return {
            # tables stacked: (T, rows, dim), sharded over "model" on T
            "tables": ParamDef((cfg.n_tables, cfg.rows_per_table, cfg.emb_dim),
                               ("expert", None, None), init="normal", dtype=ed),
            "bot": _mlp_defs(cfg.bot_mlp, cfg.n_dense, cfg.emb_dim, pd),
            "top": _mlp_defs(cfg.top_mlp, d_interact, 1, pd),
        }

    def init(self, key):
        return materialize(self.param_defs(), key)

    def param_specs(self, rules: MeshRules | None = None):
        rules = rules or MeshRules.create(self.mesh)
        return specs_of(self.param_defs(), rules)

    def _embed_bags(self, tables, idx):
        """idx: (B, T, pooling) int32 -> pooled (B, T, dim).

        Pure-jnp path (oracle); kernels/embedding_bag provides the Pallas
        TPU version, selected via cfg.use_pallas_embedding.
        """
        if self.cfg.use_pallas_embedding:
            from repro.kernels.embedding_bag.ops import embedding_bag_stacked
            return embedding_bag_stacked(tables, idx)

        def per_table(tab, ix):  # tab (rows, dim), ix (B, P)
            return tab[ix].sum(axis=1)  # (B, dim)
        pooled = jax.vmap(per_table, in_axes=(0, 1), out_axes=1)(
            tables.astype(self.compute_dtype), idx)  # (B, T, dim)
        return pooled

    def forward(self, params, batch):
        """batch: dense (B, n_dense) f32, sparse_idx (B, T, pooling) i32."""
        cfg = self.cfg
        dense = batch["dense"].astype(self.compute_dtype)
        z_bot = _mlp_apply(params["bot"], dense, len(cfg.bot_mlp))  # (B, dim)
        pooled = self._embed_bags(params["tables"], batch["sparse_idx"])  # (B,T,dim)
        feats = jnp.concatenate([z_bot[:, None], pooled], axis=1)  # (B, T+1, dim)
        inter = jnp.einsum("bid,bjd->bij", feats, feats,
                           preferred_element_type=jnp.float32)
        iu = jnp.triu_indices(feats.shape[1], k=1)
        flat = inter[:, iu[0], iu[1]].astype(self.compute_dtype)  # (B, n(n-1)/2)
        x = jnp.concatenate([flat, z_bot], axis=-1)
        return _mlp_apply(params["top"], x, len(cfg.top_mlp))[:, 0]  # (B,)

    def loss(self, params, batch):
        logit = self.forward(params, batch).astype(jnp.float32)
        y = batch["label"].astype(jnp.float32)
        return jnp.mean(jnp.maximum(logit, 0) - logit * y +
                        jnp.log1p(jnp.exp(-jnp.abs(logit))))

    def input_specs(self, global_batch: int):
        cfg = self.cfg
        return {
            "dense": jax.ShapeDtypeStruct((global_batch, cfg.n_dense), jnp.float32),
            "sparse_idx": jax.ShapeDtypeStruct((global_batch, cfg.n_tables, cfg.pooling),
                                               jnp.int32),
            "label": jax.ShapeDtypeStruct((global_batch,), jnp.float32),
        }

    def batch_pspecs(self, rules: MeshRules | None = None):
        from jax.sharding import PartitionSpec as P
        rules = rules or MeshRules.create(self.mesh)
        bt = rules.pspec(("batch",))
        b = bt[0] if len(bt) else None
        return {"dense": P(b, None), "sparse_idx": P(b, None, None), "label": P(b)}

    # --- the paper's communication profile (Fig 10): bytes per iteration ---
    def comm_profile(self) -> dict:
        """All-Reduce bytes (DP MLP grads) + All-To-All bytes (embedding)."""
        cfg = self.cfg
        mlp_params = 0
        prev = cfg.n_dense
        for h in cfg.bot_mlp:
            mlp_params += prev * h + h
            prev = h
        mlp_params += prev * cfg.emb_dim + cfg.emb_dim
        n_int = cfg.n_tables + 1
        prev = n_int * (n_int - 1) // 2 + cfg.emb_dim
        for h in cfg.top_mlp:
            mlp_params += prev * h + h
            prev = h
        mlp_params += prev + 1
        return {
            "allreduce_bytes": mlp_params * 2,  # bf16 grads
            "alltoall_bytes": 8 * 2 ** 20,      # paper: 8 MB per iteration
        }
