"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time mixing with
data-dependent per-channel decay, + squared-ReLU channel mixing.

Recurrence per head (state S: (d_k, d_v)):
    out_t = r_t . (S_t + diag(u) k_t^T v_t)
    S_t+1 = diag(w_t) S_t + k_t^T v_t
with w_t = exp(-exp(w0 + lora(x_t)))  (the data-dependent decay).

Train path: lax.scan over time.  Decode: single recurrence step.
Simplification vs the full release: the r/k/v/g token-shift lerps use static
learned mixes (the decay w keeps its full data-dependent LoRA); DESIGN.md
records this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.pytree import ParamDef


LORA_RANK = 64


def rwkv6_defs(cfg) -> dict:
    D = cfg.d_model
    H = cfg.n_heads
    dk = D // H
    return {
        "time": {
            "mu_r": ParamDef((D,), ("embed",), init="zeros"),
            "mu_k": ParamDef((D,), ("embed",), init="zeros"),
            "mu_v": ParamDef((D,), ("embed",), init="zeros"),
            "mu_w": ParamDef((D,), ("embed",), init="zeros"),
            "mu_g": ParamDef((D,), ("embed",), init="zeros"),
            "wr": ParamDef((D, D), ("embed", "heads"), init="scaled"),
            "wk": ParamDef((D, D), ("embed", "heads"), init="scaled"),
            "wv": ParamDef((D, D), ("embed", "heads"), init="scaled"),
            "wg": ParamDef((D, D), ("embed", "heads"), init="scaled"),
            "w0": ParamDef((D,), ("embed",), init="zeros"),
            "w_lora_a": ParamDef((D, LORA_RANK), ("embed", None), init="scaled"),
            "w_lora_b": ParamDef((LORA_RANK, D), (None, "embed"), init="zeros"),
            "u": ParamDef((H, dk), ("heads", None), init="zeros"),
            "ln_scale": ParamDef((D,), ("embed",), init="ones"),
            "ln_bias": ParamDef((D,), ("embed",), init="zeros"),
            "wo": ParamDef((D, D), ("heads", "embed"), init="scaled"),
        },
        "channel": {
            "mu_k": ParamDef((D,), ("embed",), init="zeros"),
            "mu_r": ParamDef((D,), ("embed",), init="zeros"),
            "wk": ParamDef((D, cfg.d_ff), ("embed", "mlp"), init="scaled"),
            "wv": ParamDef((cfg.d_ff, D), ("mlp", "embed"), init="scaled"),
            "wr": ParamDef((D, D), ("embed", "heads"), init="scaled"),
        },
    }


def _shift(x, prev_tok):
    """Token shift: x_{t-1}; prev_tok (B,D) seeds t=0 (decode carry)."""
    if x.shape[1] == 1:
        return prev_tok[:, None]
    shifted = jnp.concatenate([prev_tok[:, None], x[:, :-1]], axis=1)
    return shifted


def _lerp(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def rwkv6_time_mix(p, x, cfg, state):
    """x: (B,S,D); state: {"S": (B,H,dk,dv), "tok": (B,D)} or None."""
    B, S, D = x.shape
    H = cfg.n_heads
    dk = D // H
    prev = jnp.zeros((B, D), x.dtype) if state is None else state["tok"].astype(x.dtype)
    xs = _shift(x, prev)

    r = _lerp(x, xs, p["mu_r"]) @ p["wr"].astype(x.dtype)
    k = _lerp(x, xs, p["mu_k"]) @ p["wk"].astype(x.dtype)
    v = _lerp(x, xs, p["mu_v"]) @ p["wv"].astype(x.dtype)
    g = _lerp(x, xs, p["mu_g"]) @ p["wg"].astype(x.dtype)
    xw = _lerp(x, xs, p["mu_w"])
    w_log = (p["w0"].astype(jnp.float32)
             + jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32))
             @ p["w_lora_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w_log))  # (B,S,D) in (0,1)

    rh = r.reshape(B, S, H, dk).astype(jnp.float32)
    kh = k.reshape(B, S, H, dk).astype(jnp.float32)
    vh = v.reshape(B, S, H, dk).astype(jnp.float32)
    wh = w.reshape(B, S, H, dk)
    u = p["u"].astype(jnp.float32)

    s0 = (jnp.zeros((B, H, dk, dk), jnp.float32) if state is None
          else state["S"].astype(jnp.float32))

    Q = getattr(cfg, "rwkv_chunk", 0)
    if Q and S > Q and S % Q == 0:
        S_f, y = _chunked_time_mix(rh, kh, vh, wh, u, s0, Q)
    else:
        def step(S_c, inp):
            r_t, k_t, v_t, w_t = inp  # each (B,H,dk)
            kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,dk,dv)
            out = jnp.einsum("bhk,bhkv->bhv", r_t, S_c + u[None, :, :, None] * kv)
            S_n = w_t[..., :, None] * S_c + kv
            return S_n, out

        xs_t = tuple(jnp.moveaxis(a, 1, 0) for a in (rh, kh, vh, wh))
        S_f, outs = lax.scan(step, s0, xs_t)
        y = jnp.moveaxis(outs, 0, 1)
    y = y.reshape(B, S, D)

    # per-head group norm
    yh = y.reshape(B, S, H, dk)
    mu_ = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu_) * lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, S, D) * p["ln_scale"].astype(jnp.float32) + p["ln_bias"].astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(g))
    out = y @ p["wo"].astype(x.dtype)
    new_state = {"S": S_f, "tok": x[:, -1].astype(jnp.float32)}
    return out, new_state


def _chunked_time_mix(rh, kh, vh, wh, u, s0, Q):
    """Chunk-parallel RWKV-6 (GLA-style): one lax.scan over S/Q chunks with
    within-chunk parallel form.  All decay exponents are relative
    (la_{t-1}-la_s <= 0 for s<t; la_Q-la_s <= 0), so everything is bounded
    — no 1/A blowup.  Replaces the token-level scan whose backward
    materializes per-token state residuals (the rwkv6 train cell's memory
    wall, EXPERIMENTS.md §Perf)."""
    B, S, H, dk = rh.shape
    C = S // Q
    def resh(a):
        return jnp.moveaxis(a.reshape(B, C, Q, H, dk), 1, 0)
    rc, kc, vc, wc = resh(rh), resh(kh), resh(vh), resh(wh)

    def chunk(S_c, inp):
        r, k, v, w = inp                       # (B,Q,H,dk)
        la = jnp.cumsum(jnp.log(jnp.maximum(w, 1e-30)), axis=1)   # (B,Q,H,dk)
        la_prev = jnp.concatenate([jnp.zeros_like(la[:, :1]), la[:, :-1]], axis=1)
        # inter-chunk: r_t decayed against the incoming state
        q_eff = r * jnp.exp(la_prev)
        y_inter = jnp.einsum("bthd,bhdv->bthv", q_eff, S_c)
        # intra-chunk: scores[t,s] = sum_d r_t k_s exp(la_prev_t - la_s), s<t
        E = jnp.exp(jnp.clip(la_prev[:, :, None] - la[:, None, :], -60.0, 0.0))
        M = jnp.einsum("bthd,bshd,btshd->bths", r, k, E)
        mask = (jnp.arange(Q)[:, None] > jnp.arange(Q)[None, :])
        M = M * mask[None, :, None, :]  # M: (B, t, H, s)
        y_intra = jnp.einsum("bths,bshv->bthv", M, v)
        # diagonal bonus: (r_t . (u*k_t)) v_t
        bonus = jnp.einsum("bthd,bthd->bth", r, u[None, None] * k)
        y = y_inter + y_intra + bonus[..., None] * v
        # state to end of chunk
        decay_end = jnp.exp(la[:, -1][:, None] - la)              # (B,Q,H,dk) <= 1
        S_n = (S_c * jnp.exp(la[:, -1])[..., None]
               + jnp.einsum("bshd,bshv->bhdv", k * decay_end, v))
        return S_n, y

    S_f, ys = lax.scan(chunk, s0, (rc, kc, vc, wc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, C * Q, H, dk)
    return S_f, y


def rwkv6_channel_mix(p, x, cfg, state):
    """Squared-relu channel mixing; state: {"tok": (B,D)} or None."""
    B, S, D = x.shape
    prev = jnp.zeros((B, D), x.dtype) if state is None else state["tok"].astype(x.dtype)
    xs = _shift(x, prev)
    kx = _lerp(x, xs, p["mu_k"])
    rx = _lerp(x, xs, p["mu_r"])
    k = jnp.square(jax.nn.relu(kx @ p["wk"].astype(x.dtype)))
    out = jax.nn.sigmoid(rx @ p["wr"].astype(x.dtype)) * (k @ p["wv"].astype(x.dtype))
    return out, {"tok": x[:, -1].astype(jnp.float32)}


def rwkv6_state_defs(cfg, batch: int) -> dict:
    D = cfg.d_model
    H = cfg.n_heads
    dk = D // H
    return {
        "time": {
            "S": ParamDef((batch, H, dk, dk), ("batch", "heads", None, None), init="zeros"),
            "tok": ParamDef((batch, D), ("batch", "embed"), init="zeros"),
        },
        "channel": {
            "tok": ParamDef((batch, D), ("batch", "embed"), init="zeros"),
        },
    }
