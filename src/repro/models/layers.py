"""Shared building blocks for the model zoo (pure JAX, no framework).

Everything here is a pair of functions: ``*_defs(cfg) -> pytree[ParamDef]``
and ``*_apply(params, x, ...) -> y``.  Attention comes in three flavours:

* ``dense_attention``     — single-einsum, for short sequences / smoke tests
* ``blockwise_attention`` — lax.scan online-softmax (memory-bounded) for
                            train/prefill at 4k–32k
* ``local_attention``     — exact two-chunk sliding-window attention
* ``decode_attention``    — one-token query over a (possibly seq-sharded)
                            KV cache, stable softmax (lowers to small
                            all-reduces when the cache is sequence-parallel)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.pytree import ParamDef


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_defs(dim: int) -> dict:
    return {"scale": ParamDef((dim,), ("embed",), init="zeros")}


def rmsnorm_apply(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    # gemma-style (1 + scale): zero-init scale == identity
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(dtype)


def layernorm_defs(dim: int) -> dict:
    return {
        "scale": ParamDef((dim,), ("embed",), init="ones"),
        "bias": ParamDef((dim,), ("embed",), init="zeros"),
    }


def layernorm_apply(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    dtype = x.dtype
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def sinusoidal_at(pos: jax.Array, dim: int) -> jax.Array:
    """Sinusoidal embedding for a single (traced) position: (dim,)."""
    p = pos.astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    pe = jnp.zeros((dim,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(p * div))
    pe = pe.at[1::2].set(jnp.cos(p * div))
    return pe


def sinusoidal_pos(seq: int, dim: int, offset: int = 0) -> jax.Array:
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    pe = jnp.zeros((seq, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------

def _softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


NEG_INF = -1e30


def dense_attention(q, k, v, *, causal: bool, window: int | None = None,
                    softcap: float | None = None, prefix_len: int = 0,
                    q_offset: int = 0) -> jax.Array:
    """q: (B,Sq,Hq,D), k/v: (B,Sk,Hkv,D).  Exact reference path."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qr = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k,
                        preferred_element_type=jnp.float32)
    scores = _softcap(scores / math.sqrt(D), softcap)
    qi = jnp.arange(Sq)[:, None] + q_offset
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = ki <= qi
        if prefix_len > 0:  # prefix-LM: bidirectional over the prefix
            mask = mask | (ki < prefix_len)
    if window is not None:
        mask = mask & (ki > qi - window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, Hq, D)


def blockwise_attention(q, k, v, *, causal: bool, softcap: float | None = None,
                        prefix_len: int = 0, block_q: int = 512,
                        block_k: int = 512, split_wedge: bool = True) -> jax.Array:
    """Online-softmax blockwise attention (flash-style, pure jnp).

    Memory: O(block_q * block_k) per step instead of O(S^2).

    ``split_wedge``: for causal masks, splits the computation into the
    block-diagonal part plus a dense strictly-lower wedge processed in
    halves, avoiding the classic 2x masked-FLOP waste of naive block
    scanning (see EXPERIMENTS.md §Perf).
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    nq = S // block_q
    nk = S // block_k
    assert nq * block_q == S and nk * block_k == S, (S, block_q, block_k)

    qb = q.reshape(B, nq, block_q, Hkv, G, D)
    kb = k.reshape(B, nk, block_k, Hkv, D)
    vb = v.reshape(B, nk, block_k, Hkv, D)
    scale = 1.0 / math.sqrt(D)

    def qblock(qi, q_i):
        # scan over kv blocks with online softmax
        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_j, v_j = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            qpos = qi * block_q + jnp.arange(block_q)
            kpos = ki * block_k + jnp.arange(block_k)
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask = kpos[None, :] <= qpos[:, None]
                if prefix_len > 0:
                    mask = mask | (kpos[None, :] < prefix_len)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_j.dtype), v_j).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, block_q, D), jnp.float32)
        ks = jnp.arange(nk)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  (ks, jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B,Hkv,G,block_q,D)

    if causal and split_wedge and prefix_len == 0 and nq >= 4 and nq % 2 == 0:
        # recursive halving: top half is fully causal-local, bottom half =
        # dense rectangle over the top + causal within itself.
        return _wedge_attention(q, k, v, softcap=softcap, prefix_len=prefix_len,
                                block_q=block_q, block_k=block_k)

    outs = lax.map(lambda i: qblock(i, qb[:, i]), jnp.arange(nq))
    return _assemble(outs, B, S, Hq, D, nq, block_q).astype(q.dtype)


def _assemble(outs, B, S, Hq, D, nq, block_q):
    # outs: (nq, B, Hkv, G, block_q, D)
    out = jnp.moveaxis(outs, 0, 1)  # (B, nq, Hkv, G, bq, D)
    out = jnp.moveaxis(out, (2, 3), (3, 4))  # (B, nq, bq, Hkv, G, D)
    return out.reshape(B, S, Hq, D)


def _wedge_attention(q, k, v, *, softcap, prefix_len, block_q, block_k,
                     min_len: int = 2048):
    """Causal attention via recursive wedge split: FLOPs ~ S^2/2 exactly.

    attn(q[:h], k[:h]) causal  |  attn(q[h:], k[:h]) dense + attn(q[h:], k[h:]) causal
    The dense rectangle needs a softmax-merge with the causal part.
    """
    B, S, Hq, D = q.shape
    h = S // 2
    if S <= min_len or S % 2 != 0:
        return blockwise_attention(q, k, v, causal=True, softcap=softcap,
                                   prefix_len=prefix_len, block_q=min(block_q, S),
                                   block_k=min(block_k, S), split_wedge=False)
    top = _wedge_attention(q[:, :h], k[:, :h], v[:, :h], softcap=softcap,
                           prefix_len=prefix_len, block_q=block_q,
                           block_k=block_k, min_len=min_len)
    # bottom: merge dense-rectangle (kv first half) with causal second half
    bot = _merge_two(q[:, h:], k[:, :h], v[:, :h], k[:, h:], v[:, h:],
                     softcap=softcap, q_offset=h, prefix_len=prefix_len,
                     block_q=block_q, block_k=block_k, min_len=min_len)
    return jnp.concatenate([top, bot], axis=1)


def _partial_dense(q, k, v, *, softcap, mask=None):
    """Returns (out_unnormalized fp32, m, l) for softmax merging."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qr = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    s = _softcap(s, softcap)
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    lsum = p.sum(-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v).astype(jnp.float32)
    return out, m, lsum


def _merge_two(q, k1, v1, k2, v2, *, softcap, q_offset, prefix_len,
               block_q, block_k, min_len):
    """softmax-merge: dense attn over (k1,v1) + causal attn over (k2,v2)."""
    B, Sq, Hq, D = q.shape
    # part 1: dense rectangle, chunked over kv to bound memory
    nchunk = max(1, k1.shape[1] // max(block_k, 1))
    k1b = k1.reshape(B, nchunk, -1, *k1.shape[2:])
    v1b = v1.reshape(B, nchunk, -1, *v1.shape[2:])

    Hkv = k1.shape[2]
    G = Hq // Hkv

    def step(carry, inp):
        m, l, acc = carry
        k_j, v_j = inp
        o, m2, l2 = _partial_dense(q, k_j, v_j, softcap=softcap)
        m_new = jnp.maximum(m, m2)
        c1, c2 = jnp.exp(m - m_new), jnp.exp(m2 - m_new)
        return (m_new, l * c1 + l2 * c2, acc * c1[..., None] + o * c2[..., None]), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0),
                              (jnp.moveaxis(k1b, 1, 0), jnp.moveaxis(v1b, 1, 0)))

    # part 2: causal within second half (recursive wedge), but we need its
    # unnormalized stats — rerun its top-level merge instead: compute causal
    # part with the same chunked online softmax.
    nq2 = q.shape[1]
    qpos = jnp.arange(nq2)[:, None]
    kpos = jnp.arange(k2.shape[1])[None, :]
    causal_mask = kpos <= qpos  # both halves share offset, so relative works
    o2, m2, l2 = _partial_dense(q, k2, v2, softcap=softcap, mask=causal_mask)
    m_new = jnp.maximum(m, m2)
    c1, c2 = jnp.exp(m - m_new), jnp.exp(m2 - m_new)
    l_f = l * c1 + l2 * c2
    acc_f = acc * c1[..., None] + o2 * c2[..., None]
    out = acc_f / jnp.maximum(l_f, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1)  # (B, Sq, Hkv, G, D)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def local_attention(q, k, v, *, window: int, softcap: float | None = None) -> jax.Array:
    """Exact sliding-window causal attention via two-chunk trick.

    Chunk size = window; each query chunk attends (prev chunk ++ own chunk)
    with the exact (kpos <= qpos) & (kpos > qpos - window) mask.
    FLOPs: 2*S*window per head pair — no quadratic term.
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    W = window
    if S <= W:
        return dense_attention(q, k, v, causal=True, window=W, softcap=softcap)
    pad = (-S) % W
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = q.shape[1]
    C = Sp // W
    qc = q.reshape(B, C, W, Hq, D)
    kc = k.reshape(B, C, W, Hkv, D)
    vc = v.reshape(B, C, W, Hkv, D)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    kk = jnp.concatenate([k_prev, kc], axis=2)  # (B,C,2W,Hkv,D)
    vv = jnp.concatenate([v_prev, vc], axis=2)
    G = Hq // Hkv
    qr = qc.reshape(B, C, W, Hkv, G, D)
    s = jnp.einsum("bcqhgd,bckhd->bchgqk", qr, kk,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    s = _softcap(s, softcap)
    qpos = jnp.arange(W)[:, None] + W  # position within the 2W window frame
    kpos = jnp.arange(2 * W)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - W)
    # first chunk has no previous keys
    first = jnp.arange(C) == 0
    valid_prev = ~first[:, None, None]
    mask_c = mask[None] & (valid_prev | (kpos >= W)[None])
    s = jnp.where(mask_c[None, :, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(vv.dtype)
    o = jnp.einsum("bchgqk,bckhd->bcqhgd", p, vv)
    o = o.reshape(B, Sp, Hq, D)
    return o[:, :S]


def quantize_kv(x):
    """(B,S,H,D) -> (int8 values, per-(token,head) f32 scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def decode_attention_quant(q, k_q, v_q, k_s, v_s, *, length, softcap=None):
    """Decode attention over an int8 KV cache without materializing a
    dequantized copy: scales fold into the logits / the prob weights.

    q: (B,1,Hq,D); k_q/v_q: (B,S,Hkv,D) int8; k_s/v_s: (B,S,Hkv) f32."""
    B, _, Hq, D = q.shape
    Smax, Hkv = k_q.shape[1], k_q.shape[2]
    G = Hq // Hkv
    qr = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k_q.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    s = s * jnp.moveaxis(k_s, 2, 1)[:, :, None, :] / math.sqrt(D)
    s = _softcap(s, softcap)
    kpos = jnp.arange(Smax)[None, None, None, :]
    s = jnp.where(kpos < length, s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    pw = p * jnp.moveaxis(v_s, 2, 1)[:, :, None, :]   # fold value scales
    o = jnp.einsum("bhgk,bkhd->bhgd", pw, v_q.astype(jnp.float32))
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, length: jax.Array,
                     window: int | None = None, softcap: float | None = None) -> jax.Array:
    """q: (B,1,Hq,D) against cache (B,Smax,Hkv,D); ``length`` = #valid tokens.

    Works with a sequence-sharded cache: the softmax max/sum reductions over
    Smax lower to all-reduces under pjit (sequence-parallel decode).
    """
    B, _, Hq, D = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qr = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    s = _softcap(s, softcap)
    kpos = jnp.arange(Smax)[None, None, None, :]
    mask = kpos < length
    if window is not None:
        mask = mask & (kpos >= length - window)
    s = jnp.where(mask, s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    lsum = p.sum(-1, keepdims=True)
    o = jnp.einsum("bhgk,bkhd->bhgd", (p / jnp.maximum(lsum, 1e-30)).astype(v_cache.dtype),
                   v_cache)
    return o.reshape(B, 1, Hq, D)


# ---------------------------------------------------------------------------
# GQA attention layer (projections + rope + cache mgmt)
# ---------------------------------------------------------------------------

def gqa_defs(cfg) -> dict:
    D, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    d = {
        "wq": ParamDef((D, Hq, Dh), ("embed", "heads", None), init="scaled"),
        "wk": ParamDef((D, Hkv, Dh), ("embed", "kv_heads", None), init="scaled"),
        "wv": ParamDef((D, Hkv, Dh), ("embed", "kv_heads", None), init="scaled"),
        "wo": ParamDef((Hq, Dh, D), ("heads", None, "embed"), init="scaled"),
    }
    if cfg.qk_norm:
        d["q_norm"] = {"scale": ParamDef((Dh,), (None,), init="zeros")}
        d["k_norm"] = {"scale": ParamDef((Dh,), (None,), init="zeros")}
    return d


def _maybe_qknorm(cfg, p, q, k):
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    return q, k


def gqa_project(p, x, cfg, positions, theta):
    cdt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(cdt))
    q, k = _maybe_qknorm(cfg, p, q, k)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def gqa_out(p, o, x_dtype):
    return jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(o.dtype)).astype(x_dtype)
