"""Mixture-of-Experts FFN (DeepSeek-style: shared + routed top-k).

Three interchangeable implementations (cfg.moe_impl):

* ``dense``  — every expert computed for every token, combined by gate
               weights.  O(E/k) FLOP waste; only for tiny smoke configs.
* ``tp``     — tensor-parallel MoE: activations are replicated over the
               "model" axis, experts are sharded over it.  Dispatch is a
               *local* capacity scatter on each shard (zero communication);
               combine is a psum over "model" (the same all-reduce any TP
               layer needs).  Default for the dry-run cells.
* ``ep_a2a`` — true expert parallelism: experts sharded over the token
               ("data") axis, dispatch/combine via lax.all_to_all.  This is
               the DeepSeek deployment style and produces the All-To-All
               network traffic the paper studies.  Selectable per config.

Token-choice top-k routing with per-expert capacity dropping (GShard);
gates renormalized over the kept top-k.  Dispatch never materializes the
(T*k, D) repeated-token tensor: tokens are scattered slot-by-slot (k small
scatters of (T, D)) into the capacity buffer.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.common.pytree import ParamDef


def moe_defs(cfg) -> dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    d = {
        "router": ParamDef((D, E), ("embed", None), init="scaled"),
        "w1": ParamDef((E, D, F), ("expert", "embed", "mlp"), init="scaled"),
        "w3": ParamDef((E, D, F), ("expert", "embed", "mlp"), init="scaled"),
        "w2": ParamDef((E, F, D), ("expert", "mlp", "embed"), init="scaled"),
    }
    if cfg.n_shared_experts:
        Fs = cfg.moe_d_ff * cfg.n_shared_experts
        d["shared"] = {
            "w1": ParamDef((D, Fs), ("embed", "mlp"), init="scaled"),
            "w3": ParamDef((D, Fs), ("embed", "mlp"), init="scaled"),
            "w2": ParamDef((Fs, D), ("mlp", "embed"), init="scaled"),
        }
    return d


def _router(router_w, x, cfg):
    """x: (T, D) -> (gates, idx): (T, k).  fp32 routing."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32)) * cfg.router_scale
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def _expert_ffn(w1, w3, w2, xb):
    """xb: (E_loc, C, D); weights (E_loc, D, F)/(E_loc, F, D)."""
    h = jnp.einsum("ecd,edf->ecf", xb, w1.astype(xb.dtype))
    g = jnp.einsum("ecd,edf->ecf", xb, w3.astype(xb.dtype))
    h = jax.nn.silu(h) * g
    return jnp.einsum("ecf,efd->ecd", h, w2.astype(xb.dtype))


def _shared_ffn(p, x):
    h = jax.nn.silu(x @ p["w1"].astype(x.dtype)) * (x @ p["w3"].astype(x.dtype))
    return h @ p["w2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# slot-wise capacity dispatch helpers
# ---------------------------------------------------------------------------

def _positions(idx2d, keep2d, n_buckets, cap):
    """Per-(token,slot) position within its destination bucket.

    idx2d/keep2d: (T, k) -> (pos2d, kept2d), row-major arrival order.
    """
    T, k = idx2d.shape
    flat = idx2d.reshape(-1)
    keep = keep2d.reshape(-1)
    oh = jax.nn.one_hot(flat, n_buckets, dtype=jnp.int32) * keep.astype(jnp.int32)[:, None]
    pre = jnp.cumsum(oh, axis=0) - oh
    pos = (pre * oh).sum(-1)
    kept = keep & (pos < cap)
    return pos.reshape(T, k), kept.reshape(T, k)


def _scatter_slots(x, idx2d, pos2d, kept2d, n_buckets, cap):
    """k scatters of (T, D) rows into (n_buckets, cap, D) — no (T*k, D)."""
    buf = jnp.zeros((n_buckets, cap, x.shape[-1]), x.dtype)
    for j in range(idx2d.shape[1]):
        buf = buf.at[idx2d[:, j], pos2d[:, j]].add(
            x * kept2d[:, j, None].astype(x.dtype), mode="drop")
    return buf


def _gather_slots(y, idx2d, pos2d, kept2d, gates):
    """Inverse of _scatter_slots, weighted by gates: (T, D)."""
    out = jnp.zeros((idx2d.shape[0], y.shape[-1]), y.dtype)
    for j in range(idx2d.shape[1]):
        w = (kept2d[:, j].astype(y.dtype) * gates[:, j].astype(y.dtype))[:, None]
        out = out + y[idx2d[:, j], pos2d[:, j]] * w
    return out


# ---------------------------------------------------------------------------
# dense fallback (smoke tests)
# ---------------------------------------------------------------------------

def _moe_dense(p, x, cfg):
    gates, idx = _router(p["router"], x, cfg)
    h = jnp.einsum("td,edf->tef", x, p["w1"].astype(x.dtype))
    g = jnp.einsum("td,edf->tef", x, p["w3"].astype(x.dtype))
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * g, p["w2"].astype(x.dtype))
    sel = jnp.take_along_axis(y, idx[:, :, None], axis=1)  # (T,k,D)
    return (sel * gates[:, :, None].astype(x.dtype)).sum(1)


# ---------------------------------------------------------------------------
# TP MoE: experts over "model", tokens replicated over "model"
# ---------------------------------------------------------------------------

def _moe_tp_local(router_w, w1, w3, w2, x, *, cfg, n_model, model_axis):
    """Per-shard body (inside shard_map).  x: (T_loc, D) replicated over
    ``model_axis``; w*: local expert slices (E_loc, ...)."""
    E = cfg.n_experts
    E_loc = E // n_model
    my = lax.axis_index(model_axis)
    gates, idx = _router(router_w, x, cfg)  # full-E routing, identical on shards

    mine = (idx >= my * E_loc) & (idx < (my + 1) * E_loc)
    e_local = jnp.clip(idx - my * E_loc, 0, E_loc - 1)
    Tk = idx.size
    cap = max(1, int(cfg.capacity_factor * Tk / max(n_model * E_loc, 1)))

    pos, kept = _positions(e_local, mine, E_loc, cap)
    buf = _scatter_slots(x, e_local, pos, kept, E_loc, cap)
    y = _expert_ffn(w1, w3, w2, buf)
    out = _gather_slots(y, e_local, pos, kept, gates)
    return lax.psum(out, model_axis)


# ---------------------------------------------------------------------------
# EP MoE: experts over "data", dispatch via all_to_all
# ---------------------------------------------------------------------------

def _moe_ep_local(router_w, w1, w3, w2, x, *, cfg, n_data, data_axis, model_axis):
    """Per-shard body.  x: (T_loc, D) sharded over ``data_axis``; experts
    sharded over the same axis (E_loc per shard); expert d_ff sharded over
    ``model_axis`` (TP-within-expert, psum combine).  Dispatch + combine are
    each one lax.all_to_all over ``data_axis`` — the paper's A2A traffic."""
    E = cfg.n_experts
    E_loc = E // n_data
    gates, idx = _router(router_w, x, cfg)
    dst = idx // E_loc                       # destination data shard (T,k)
    Tk = idx.size
    cap = max(1, int(cfg.capacity_factor * Tk / n_data))

    pos, kept = _positions(dst, jnp.ones_like(dst, bool), n_data, cap)
    send = _scatter_slots(x, dst, pos, kept, n_data, cap)
    # metadata rides along: local expert id within destination, +1 so that
    # empty slots (0) mark invalid rows after the exchange.
    meta = jnp.zeros((n_data, cap), jnp.int32)
    for j in range(idx.shape[1]):
        meta = meta.at[dst[:, j], pos[:, j]].add(
            jnp.where(kept[:, j], idx[:, j] % E_loc + 1, 0), mode="drop")

    recv = lax.all_to_all(send, data_axis, split_axis=0, concat_axis=0, tiled=True)
    meta_r = lax.all_to_all(meta, data_axis, split_axis=0, concat_axis=0, tiled=True)

    rows = recv.reshape(-1, x.shape[-1])            # (n_data*cap, D)
    e_of_row = meta_r.reshape(-1)                   # 0 = empty, else e_local+1
    valid = (e_of_row > 0)[:, None]
    e_row = jnp.clip(e_of_row - 1, 0, E_loc - 1)[:, None]
    cap2 = max(1, int(cfg.capacity_factor * rows.shape[0] / max(E_loc, 1)))
    pos2, kept2 = _positions(e_row, valid, E_loc, cap2)
    buf = _scatter_slots(rows, e_row, pos2, kept2, E_loc, cap2)
    y = _expert_ffn(w1, w3, w2, buf)                # partial over model (F sharded)
    y = lax.psum(y, model_axis)
    ones = jnp.ones((rows.shape[0], 1), y.dtype)
    back_rows = _gather_slots(y, e_row, pos2, kept2, ones)
    back = back_rows.reshape(n_data, cap, x.shape[-1])
    ret = lax.all_to_all(back, data_axis, split_axis=0, concat_axis=0, tiled=True)
    return _gather_slots(ret, dst, pos, kept, gates)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

def moe_apply(p, x2d, cfg, mesh=None):
    """x2d: (T, D) -> (T, D).  Routed experts + shared experts."""
    impl = cfg.moe_impl
    if mesh is None or impl == "dense" or "model" not in getattr(mesh, "axis_names", ()):
        routed = _moe_chunked(lambda xs: _moe_dense(p, xs, cfg), x2d, cfg, mesh)
    elif impl == "tp":
        n_model = mesh.shape["model"]
        batch_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        fn = shard_map(
            partial(_moe_tp_local, cfg=cfg, n_model=n_model, model_axis="model"),
            mesh=mesh,
            in_specs=(P(None, None), P("model", None, None), P("model", None, None),
                      P("model", None, None), P(batch_axes, None)),
            out_specs=P(batch_axes, None),
            check_rep=False,
        )
        routed = _moe_chunked(
            lambda xs: fn(p["router"], p["w1"], p["w3"], p["w2"], xs), x2d, cfg, mesh)
    elif impl == "ep_a2a":
        n_data = mesh.shape["data"]
        batch_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        fn = shard_map(
            partial(_moe_ep_local, cfg=cfg, n_data=n_data, data_axis="data",
                    model_axis="model"),
            mesh=mesh,
            in_specs=(P(None, None), P("data", None, "model"), P("data", None, "model"),
                      P("data", "model", None), P(batch_axes, None)),
            out_specs=P(batch_axes, None),
            check_rep=False,
        )
        routed = _moe_chunked(
            lambda xs: fn(p["router"], p["w1"], p["w3"], p["w2"], xs), x2d, cfg, mesh)
    else:
        raise ValueError(f"unknown moe_impl {impl}")

    if cfg.n_shared_experts:
        routed = routed + _shared_ffn(p["shared"], x2d)
    return routed


def _batch_shards(mesh) -> int:
    if mesh is None:
        return 1
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= int(mesh.shape[a])
    return n


def _moe_chunked(fn, x2d, cfg, mesh=None):
    """Process tokens in cfg.moe_chunks microchunks to bound dispatch
    buffer memory (DESIGN.md §5).  Chunks must stay divisible by the
    token-sharding factor, so n is reduced as needed."""
    n = cfg.moe_chunks
    T = x2d.shape[0]
    shards = _batch_shards(mesh)
    while n > 1 and (T % n != 0 or (T // n) % shards != 0):
        n //= 2
    if n <= 1:
        return fn(x2d)
    xc = x2d.reshape(n, T // n, -1)
    yc = lax.map(fn, xc)
    return yc.reshape(T, -1)


# EP sharding overrides for ep_a2a mode (expert dim over data, F over model)
def moe_param_overrides(cfg) -> dict | None:
    """Sharding-rule overrides needed by the chosen impl."""
    if cfg.moe_impl == "ep_a2a":
        return {"expert": ("data",)}
    return None
