"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<N>/{manifest.json, <flat.param.path>.npy ...}

* atomic: written to ``step_<N>.tmp`` then os.rename'd — a crash mid-write
  never corrupts the latest checkpoint.
* async: AsyncCheckpointer copies arrays to host and writes on a worker
  thread so the train loop doesn't block (double-buffered).
* elastic: restore() takes the *new* mesh + specs; arrays are re-laid-out
  by jax.device_put, so a checkpoint from a 256-chip run restores onto any
  other mesh factorization.
* multi-host note: on a real cluster each process would write only the
  addressable shards of each array (path suffix .shard<k>) — on this
  single-process runtime every array is fully addressable, so one file per
  leaf suffices; the manifest format already carries the pspec for that
  extension.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.pytree import flatten_with_paths


def _spec_to_json(spec) -> list:
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, tuple):
            out.append(list(e))
        else:
            out.append(e)
    return out


def save(ckpt_dir: str, step: int, tree: Any, specs: Any | None = None,
         extra_meta: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = flatten_with_paths(tree)
    spec_leaves = dict(flatten_with_paths(
        specs, is_leaf=lambda x: isinstance(x, P))) if specs is not None else {}
    manifest = {"step": step, "leaves": {}, "meta": extra_meta or {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype in ("bfloat16",):
            # numpy can't serialize ml_dtypes (bf16 -> '|V2'); store the
            # lossless fp32 widening and record the logical dtype
            arr = np.asarray(jnp.asarray(leaf).astype(jnp.float32))
            logical_dtype = "bfloat16"
        fn = name.replace("/", "_") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        entry = {"file": fn, "shape": list(arr.shape), "dtype": logical_dtype}
        if name in spec_leaves:
            entry["pspec"] = _spec_to_json(spec_leaves[name])
        manifest["leaves"][name] = entry
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, mesh=None, specs: Any | None = None):
    """``like``: pytree with the target structure (values ignored)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    names = [n for n, _ in flatten_with_paths(like)]
    spec_list = ([s for _, s in flatten_with_paths(specs, is_leaf=lambda x: isinstance(x, P))]
                 if specs is not None else [None] * len(names))
    leaves = []
    for name, spec in zip(names, spec_list):
        entry = manifest["leaves"][name]
        arr = np.load(os.path.join(path, entry["file"]))
        if entry["dtype"] == "bfloat16" and arr.dtype != jnp.bfloat16:
            arr = jnp.asarray(arr).astype(jnp.bfloat16)
        if mesh is not None and spec is not None:
            leaves.append(jax.device_put(arr, NamedSharding(mesh, spec)))
        else:
            leaves.append(jnp.asarray(arr))
    flat, tdef = jax.tree.flatten(like)
    assert len(flat) == len(leaves), (len(flat), len(leaves))
    return jax.tree.unflatten(tdef, leaves), manifest["meta"]


def gc_old(ckpt_dir: str, keep: int):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Fire-and-forget checkpoint writes on a worker thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._pending: threading.Thread | None = None
        self._err: Exception | None = None

    def save(self, step: int, tree, specs=None, extra_meta=None):
        self.wait()
        # device_get on the main thread (cheap host copy), write on worker
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.dir, step, host_tree, specs, extra_meta)
                gc_old(self.dir, self.keep)
            except Exception as e:  # surfaced on next wait()
                self._err = e

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err
