# TPU Pallas kernels for the compute hot-spots of this system:
#   embedding_bag — DLRM multi-hot pooled lookup (the paper's workload)
#   flash_decode  — chunked-KV decode attention (serving shape cells)
#   cc_update     — fused DCQCN per-flow state update (the simulator's
#                   inner loop when sweeping CC configs on-TPU)
# Each has ops.py (jit wrapper) + ref.py (pure-jnp oracle) + allclose tests.
