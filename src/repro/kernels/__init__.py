# TPU Pallas kernels for the compute hot-spots of this system:
#   embedding_bag — DLRM multi-hot pooled lookup (the paper's workload)
#   flash_decode  — chunked-KV decode attention (serving shape cells)
#   cc_update     — fused DCQCN per-flow state update (the simulator's
#                   inner loop when sweeping CC configs on-TPU)
#   engine_step   — fused engine signals + generic policy update and the
#                   padded-gather segment reduction (the simulator's full
#                   stage-1/2 hot loop; see repro.core.engine step_impl)
# Each has ops.py (jit wrapper) + ref.py (pure-jnp oracle) + allclose tests.
from __future__ import annotations

import jax


def default_interpret(interpret: bool | None = None) -> bool:
    """Resolve the kernel ``interpret`` convention.

    ``None`` (the default everywhere) auto-detects: compiled Mosaic on TPU,
    interpret mode elsewhere (CPU test runs, GPU without Mosaic lowering).
    Pass an explicit bool to force either path.
    """
    if interpret is not None:
        return bool(interpret)
    return jax.default_backend() != "tpu"
