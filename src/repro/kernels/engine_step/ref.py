"""Oracle: engine stages 1-2 (and the gather reduction) in pure jnp.

Mirrors ``repro.core.engine._make_step``'s signal formulas and
``_reduce``'s "gather" strategy exactly, so the kernel allclose tests pin
the fused Pallas path to the engine's jnp semantics.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.cc import Signals


def fused_step_ref(policy, *, q_d, tx_d, caps, ecn_mask, hopmask,
                   kmin_h, kmax_h, pmax_h, base_rtt, line, loss,
                   state: dict, params: dict, t, dt: float,
                   t_base_util: float):
    """Flat-array reference for ``ops.fused_step`` (same signature minus
    ``interpret``): returns ``(state', rate, win)``."""
    hopmask = hopmask.astype(bool)
    rtt = base_rtt + (q_d / caps * hopmask).sum(1)
    mark = jnp.clip((q_d - kmin_h) / jnp.maximum(kmax_h - kmin_h, 1.0),
                    0.0, 1.0) * pmax_h
    mark = mark * ecn_mask
    ecn = 1.0 - jnp.prod(1.0 - mark, axis=1)
    util_l = tx_d / caps + q_d / (caps * t_base_util)
    util = jnp.max(jnp.where(hopmask, util_l, 0.0), axis=1)
    sig = Signals(ecn=ecn, rtt=rtt, util=util,
                  t=jnp.asarray(t, jnp.float32), dt=jnp.float32(dt),
                  line=line, base_rtt=base_rtt, loss=loss)
    st2, rate, win = policy.update(dict(policy.params, **(params or {})),
                                   state, sig)
    F = line.shape[0]
    return (st2, jnp.broadcast_to(rate, (F,)), jnp.broadcast_to(win, (F,)))


def segment_reduce_ref(vals, idx, n_out: int, C: int):
    """``engine._reduce``'s "gather" strategy verbatim."""
    rows = vals.at[idx].get(mode="fill", fill_value=0.0)
    return rows.reshape(n_out, C).sum(axis=1)


def segment_reduce_pfc_ref(vals, idx, n_out: int, C: int, xoff, xon,
                           can_pause, prev_paused):
    """Gather reduction + the engine's PFC hysteresis (stages 6-7)."""
    q = segment_reduce_ref(vals, idx, n_out, C)
    over = (q > xoff) & can_pause
    under = q < xon
    paused = jnp.where(over, True, jnp.where(under, False, prev_paused))
    return q, paused
