# Fused engine-step kernels: signals+policy update and padded-gather
# segment reduction.  ops.py (flat wrappers the engine dispatches to),
# engine_step.py (tiled pallas_calls), ref.py (pure-jnp oracle).
from repro.kernels.engine_step.ops import (fused_step,  # noqa: F401
                                           segment_reduce,
                                           segment_reduce_pfc)
