"""Wrappers: the engine's flat per-flow/per-link arrays -> tiled Pallas
engine-step kernels -> flat.

``fused_step`` is the entry point ``repro.core.engine`` dispatches to when
``step_impl`` resolves to "pallas" (see ``engine.resolve_step_impl``):
it pads the (F, MAXHOP) hop arrays and (F,) flow arrays to (8, 128) tiles,
packs the policy state/params via the ``cc`` flat-array tables, runs the
fused signals+policy kernel and unpacks.  ``segment_reduce`` /
``segment_reduce_pfc`` wrap the padded-gather reduction the same way for
``engine._reduce``'s "gather" strategy.

Padding is inert by construction: padded lanes get neutral values (caps 1,
kmax > kmin, masks 0) so no NaN/Inf can leak out of discarded lanes, and
outputs are sliced back to the live prefix.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import cc as cc_mod
from repro.kernels import default_interpret
from repro.kernels.engine_step.engine_step import (
    fused_signals_policy_tiled, segment_reduce_pfc_tiled,
    segment_reduce_tiled)


def _tile_flat(x, n_pad, fill=0.0):
    """(F,) -> (1, N8, 128)."""
    return jnp.pad(x.astype(jnp.float32), (0, n_pad),
                   constant_values=fill).reshape(1, -1, 128)


def _tile_hop(x, n_pad, fill=0.0):
    """(F, H) -> (1, H, N8, 128)."""
    xt = jnp.pad(x.astype(jnp.float32).T, ((0, 0), (0, n_pad)),
                 constant_values=fill)
    return xt.reshape(1, xt.shape[0], -1, 128)


def fused_step(policy, *, q_d, tx_d, caps, ecn_mask, hopmask,
               kmin_h, kmax_h, pmax_h, base_rtt, line, loss,
               state: dict, params: dict, t, dt: float, t_base_util: float,
               interpret: bool | None = None):
    """Engine stages 1-2 in one fused kernel call.

    Hop-shaped inputs are (F, MAXHOP); flow-shaped inputs are (F,);
    ``state``/``params`` are the policy's dict forms (packed internally
    via ``cc.pack_state``/``cc.pack_params``).  Returns ``(state', rate,
    win)`` matching ``policy.update``'s contract on flat (F,) arrays.
    """
    interpret = default_interpret(interpret)
    F = line.shape[0]
    n_pad = (-F) % 128
    Fp = F + n_pad
    hop_inputs = (
        _tile_hop(q_d, n_pad),
        _tile_hop(tx_d, n_pad),
        _tile_hop(caps, n_pad, fill=1.0),
        _tile_hop(ecn_mask, n_pad),
        _tile_hop(hopmask.astype(jnp.float32), n_pad),
        _tile_hop(kmin_h, n_pad, fill=1.0),
        _tile_hop(kmax_h, n_pad, fill=2.0),
        _tile_hop(pmax_h, n_pad),
    )
    flat_inputs = (
        _tile_flat(base_rtt, n_pad, fill=1.0),
        _tile_flat(line, n_pad, fill=1.0),
        _tile_flat(loss, n_pad),
    )
    packed = cc_mod.pack_state(policy, state, n_flows=F)
    st4d = jnp.pad(packed, ((0, 0), (0, n_pad)),
                   constant_values=1.0).reshape(1, packed.shape[0], -1, 128)
    p2d = cc_mod.pack_params(policy, params).reshape(1, -1)
    st_out, rate, win, _, _, _ = fused_signals_policy_tiled(
        policy, hop_inputs, flat_inputs, st4d, p2d, t,
        dt=dt, t_base_util=t_base_util, interpret=interpret)
    keys = cc_mod.kernel_state_keys(policy)
    new_state = {k: st_out[0, j].reshape(Fp)[:F]
                 for j, k in enumerate(keys)}
    return (new_state,
            rate[0].reshape(Fp)[:F],
            win[0].reshape(Fp)[:F])


def _pack_seg(vals, idx, n_out: int, C: int):
    """Pad gather operands to kernel tiles: vals to a (V8, 128) grid with
    a zero tail (every OOB index clamps there), the flat (n_out*C,) index
    matrix to one 128-lane row per segment, rows padded to a multiple of
    8."""
    n_in = vals.shape[0]
    v_pad = (-(n_in + 1)) % 128 + 1              # >= 1 zero slot
    vals2d = jnp.pad(vals.astype(jnp.float32), (0, v_pad)).reshape(-1, 128)
    idx2d = idx.reshape(n_out, C)
    idx2d = jnp.pad(idx2d, ((0, (-n_out) % 8), (0, 128 - C)),
                    constant_values=n_in)
    idx2d = jnp.minimum(idx2d, n_in).astype(jnp.int32)
    return vals2d, idx2d


def segment_reduce(vals, idx, n_out: int, C: int,
                   interpret: bool | None = None):
    """The "gather" strategy of ``engine._reduce_plan``: ``out[s] =
    sum(vals[idx[s*C:(s+1)*C]])`` with OOB fill 0, as a Pallas row-sum.
    ``idx`` is the plan's flat (n_out*C,) int32 matrix."""
    interpret = default_interpret(interpret)
    vals2d, idx2d = _pack_seg(vals, idx, n_out, C)
    out = segment_reduce_tiled(vals2d, idx2d, interpret=interpret)
    return out[:n_out, 0]


def _lane_bcast(x, rows: int, fill=0.0):
    """(n_out,) per-segment scalar -> (rows, 128) lane-broadcast tile."""
    x = jnp.pad(x.astype(jnp.float32), (0, rows - x.shape[0]),
                constant_values=fill)
    return jnp.broadcast_to(x[:, None], (rows, 128))


def segment_reduce_pfc(vals, idx, n_out: int, C: int, xoff, xon, can_pause,
                       prev_paused, interpret: bool | None = None):
    """Fused per-port occupancy reduction + PFC hysteresis (engine stages
    6-7 for the pause signal): returns ``(q_port, paused)`` with ``paused``
    boolean, matching the jnp path's ``where(over, True, where(under,
    False, prev))``."""
    interpret = default_interpret(interpret)
    vals2d, idx2d = _pack_seg(vals, idx, n_out, C)
    rows = idx2d.shape[0]
    q, paused = segment_reduce_pfc_tiled(
        vals2d, idx2d,
        _lane_bcast(xoff, rows, fill=jnp.inf),
        _lane_bcast(xon, rows),
        _lane_bcast(can_pause.astype(jnp.float32), rows),
        _lane_bcast(prev_paused.astype(jnp.float32), rows),
        interpret=interpret)
    return q[:n_out, 0], paused[:n_out, 0] > 0.5
