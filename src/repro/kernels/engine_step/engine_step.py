"""Pallas TPU kernels for the fluid engine's hot inner loop.

Two kernels cover the per-step work that dominates the simulator when
sweeping CC x fabric x fault grids (see ``repro.core.engine`` stages 1-7):

``fused_signals_policy_tiled``
    Stages 1-2 fused into one VPU pass: ECN-mark product, queueing-delay
    RTT and HPCC INT utilisation across the flow's MAXHOP path slots,
    feeding directly into the *generic* per-flow policy state update — any
    kernel-eligible registered policy (all eight, the learned ``mlp``
    included: the ``Signals``-driven update is pure elementwise jnp, so
    the same tiled body runs DCQCN and HPCC alike; cf. the DCQCN-only
    ``kernels/cc_update``).  Flows tile
    (8, 128) (sublane x lane); the sweep batch axis is folded into the
    leading grid dimension, so a B-lane vmapped sweep is one grid of
    B x N8/8 tiles instead of B separate dispatches.

``segment_reduce_tiled`` / ``segment_reduce_pfc_tiled``
    The engine's padded-gather segment reduction (``_reduce_plan``'s
    "gather" strategy): ``out[s] = sum(vals[idx[s, :]])`` over a static
    (n_out, C) index matrix, C <= 64 padded to one 128-lane row per
    segment.  The ``_pfc`` variant fuses the PFC X_OFF/X_ON hysteresis on
    the reduced per-port occupancy, collapsing engine stages 6-7 for the
    pause signal into the same pass.

Params ride in SMEM as a packed (B, P) row per batch lane (sorted-key
order from ``cc.kernel_param_keys``), so CC-parameter sweeps stay traced —
no recompile per parameter point, matching the engine contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import cc as cc_mod


# ---------------------------------------------------------------------------
# kernel A: fused delayed-signal computation + generic policy update
# ---------------------------------------------------------------------------

def _signals_policy_kernel(qd_ref, txd_ref, caps_ref, emask_ref, hmask_ref,
                           kmin_ref, kmax_ref, pmax_ref,
                           brtt_ref, line_ref, loss_ref,
                           state_ref, params_ref, t_ref,
                           o_state, o_rate, o_win, o_ecn, o_rtt, o_util,
                           *, update, state_keys, param_keys, dt,
                           t_base_util, maxhop):
    t = t_ref[0, 0]
    base_rtt = brtt_ref[0]                       # (bs, 128)
    line = line_ref[0]
    loss = loss_ref[0]
    shape = line.shape

    # stage 1: ECN-mark product, queueing RTT, INT utilisation over hops
    rtt = base_rtt
    unmarked = jnp.ones(shape, jnp.float32)
    util = jnp.zeros(shape, jnp.float32)
    for h in range(maxhop):
        q_d = qd_ref[0, h]
        tx_d = txd_ref[0, h]
        caps = caps_ref[0, h]
        hm = hmask_ref[0, h]
        mark = jnp.clip((q_d - kmin_ref[0, h])
                        / jnp.maximum(kmax_ref[0, h] - kmin_ref[0, h], 1.0),
                        0.0, 1.0) * pmax_ref[0, h] * emask_ref[0, h]
        unmarked = unmarked * (1.0 - mark)
        rtt = rtt + q_d / caps * hm
        util_l = tx_d / caps + q_d / (caps * t_base_util)
        util = jnp.maximum(util, jnp.where(hm > 0, util_l, 0.0))
    ecn = 1.0 - unmarked

    # stage 2: the policy's Signals-driven state update (elementwise jnp,
    # so the registered updates run on (bs, 128) tiles unchanged)
    sig = cc_mod.Signals(ecn=ecn, rtt=rtt, util=util, t=t,
                         dt=jnp.float32(dt), line=line, base_rtt=base_rtt,
                         loss=loss)
    params = {k: params_ref[0, j] for j, k in enumerate(param_keys)}
    state = {k: state_ref[0, j] for j, k in enumerate(state_keys)}
    st2, rate, win = update(params, state, sig)
    for j, k in enumerate(state_keys):
        o_state[0, j] = st2[k]
    if not state_keys:                           # stateless: dummy row
        o_state[0, 0] = jnp.zeros(shape, jnp.float32)
    o_rate[0] = rate
    o_win[0] = win
    o_ecn[0] = ecn
    o_rtt[0] = rtt
    o_util[0] = util


def fused_signals_policy_tiled(policy, hop_inputs: tuple, flat_inputs: tuple,
                               state4d: jax.Array, params2d: jax.Array,
                               t: jax.Array, *, dt: float,
                               t_base_util: float, interpret: bool):
    """Run the fused stage-1/2 kernel on tiled inputs.

    ``hop_inputs``: 8-tuple (q_d, tx_d, caps, ecn_mask, hopmask, kmin,
    kmax, pmax), each (B, H, N8, 128) float32; ``flat_inputs``: 3-tuple
    (base_rtt, line, loss), each (B, N8, 128); ``state4d``: (B, K, N8,
    128) packed in ``cc.kernel_state_keys`` order (K >= 1); ``params2d``:
    (B, P) packed in ``cc.kernel_param_keys`` order (P >= 1); ``t``:
    scalar sim time.  Returns (state', rate, win, ecn, rtt, util) with the
    input shapes.  The batch axis B is the leading grid dimension.
    """
    state_keys = cc_mod.kernel_state_keys(policy)
    if state_keys is None:
        raise ValueError(f"policy {policy.name!r} is not kernel-eligible")
    param_keys = cc_mod.kernel_param_keys(policy)
    update = cc_mod.flat_update(policy)

    B, H, N8, _ = hop_inputs[0].shape
    K = state4d.shape[1]
    P = params2d.shape[1]
    bs = min(8, N8)
    hop_spec = pl.BlockSpec((1, H, bs, 128), lambda b, i: (b, 0, i, 0))
    flat_spec = pl.BlockSpec((1, bs, 128), lambda b, i: (b, i, 0))
    st_spec = pl.BlockSpec((1, K, bs, 128), lambda b, i: (b, 0, i, 0))
    p_spec = pl.BlockSpec((1, P), lambda b, i: (b, 0),
                          memory_space=pltpu.SMEM)
    t_spec = pl.BlockSpec((1, 1), lambda b, i: (0, 0),
                          memory_space=pltpu.SMEM)
    out_shape = [jax.ShapeDtypeStruct((B, K, N8, 128), jnp.float32)] \
        + [jax.ShapeDtypeStruct((B, N8, 128), jnp.float32)] * 5
    kernel = functools.partial(
        _signals_policy_kernel, update=update, state_keys=state_keys,
        param_keys=param_keys, dt=float(dt),
        t_base_util=float(t_base_util), maxhop=H)
    return pl.pallas_call(
        kernel,
        grid=(B, N8 // bs),
        in_specs=[hop_spec] * 8 + [flat_spec] * 3 + [st_spec, p_spec,
                                                     t_spec],
        out_specs=[st_spec] + [flat_spec] * 5,
        out_shape=out_shape,
        interpret=interpret,
    )(*hop_inputs, *flat_inputs, state4d, params2d,
      jnp.asarray(t, jnp.float32).reshape(1, 1))


# ---------------------------------------------------------------------------
# kernel B: padded-gather segment reduction (+ fused PFC hysteresis)
# ---------------------------------------------------------------------------

def _seg_kernel(vals_ref, idx_ref, o_ref):
    v = vals_ref[...]                            # (V8, 128) whole array
    idx = idx_ref[...]                           # (bs, 128) int32
    rows = v[idx // 128, idx % 128]              # gather; OOB -> zero pad
    s = jnp.sum(rows, axis=1, keepdims=True)
    o_ref[...] = jnp.broadcast_to(s, idx.shape)


def _seg_pfc_kernel(vals_ref, idx_ref, xoff_ref, xon_ref, can_ref, prev_ref,
                    o_q, o_paused):
    v = vals_ref[...]
    idx = idx_ref[...]
    rows = v[idx // 128, idx % 128]
    q = jnp.broadcast_to(jnp.sum(rows, axis=1, keepdims=True), idx.shape)
    over = (q > xoff_ref[...]) & (can_ref[...] > 0)
    under = q < xon_ref[...]
    paused = jnp.where(over, 1.0,
                       jnp.where(under, 0.0, prev_ref[...]))
    o_q[...] = q
    o_paused[...] = paused


def segment_reduce_tiled(vals2d: jax.Array, idx2d: jax.Array, *,
                         interpret: bool) -> jax.Array:
    """``out[r] = sum(vals2d.flat[idx2d[r, :]])`` per padded segment row.

    ``vals2d``: (V8, 128) float32 with zero slots appended past the live
    values (every out-of-bounds index in ``idx2d`` points there);
    ``idx2d``: (R, 128) int32, one 128-lane row per output segment.
    Returns (R, 128) with the row sum broadcast across lanes.
    """
    V8 = vals2d.shape[0]
    R = idx2d.shape[0]
    bs = min(8, R)
    vspec = pl.BlockSpec((V8, 128), lambda r: (0, 0))
    ispec = pl.BlockSpec((bs, 128), lambda r: (r, 0))
    return pl.pallas_call(
        _seg_kernel,
        grid=(R // bs,),
        in_specs=[vspec, ispec],
        out_specs=ispec,
        out_shape=jax.ShapeDtypeStruct((R, 128), jnp.float32),
        interpret=interpret,
    )(vals2d, idx2d)


def segment_reduce_pfc_tiled(vals2d, idx2d, xoff2d, xon2d, can2d, prev2d, *,
                             interpret: bool):
    """``segment_reduce_tiled`` with the PFC X_OFF/X_ON hysteresis fused:
    per segment (= per ingress port) ``paused' = over ? 1 : under ? 0 :
    prev`` where over keys on ``xoff``/``can`` and under on ``xon``.  The
    per-port scalars arrive lane-broadcast as (R, 128).  Returns
    ``(q, paused)``, both (R, 128)."""
    V8 = vals2d.shape[0]
    R = idx2d.shape[0]
    bs = min(8, R)
    vspec = pl.BlockSpec((V8, 128), lambda r: (0, 0))
    ispec = pl.BlockSpec((bs, 128), lambda r: (r, 0))
    return pl.pallas_call(
        _seg_pfc_kernel,
        grid=(R // bs,),
        in_specs=[vspec] + [ispec] * 5,
        out_specs=[ispec, ispec],
        out_shape=[jax.ShapeDtypeStruct((R, 128), jnp.float32)] * 2,
        interpret=interpret,
    )(vals2d, idx2d, xoff2d, xon2d, can2d, prev2d)
