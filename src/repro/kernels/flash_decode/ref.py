"""Pure-jnp oracle for flash_decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_decode_ref(q, k, v, length):
    """q: (B,Hkv,G,D); k/v: (B,S,Hkv,D); length (B,) -> (B,Hkv,G,D)."""
    B, Hkv, G, D = q.shape
    S = k.shape[1]
    logits = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (D ** 0.5)
    mask = jnp.arange(S)[None, None, None, :] < length[:, None, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
