"""jit'd wrapper: GQA decode attention with the Pallas flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.flash_decode import flash_decode


def gqa_decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         length: jax.Array, block_s: int = 256,
                         interpret: bool = True) -> jax.Array:
    """q: (B, 1, Hq, D) over cache (B, S, Hkv, D); length () or (B,).

    Drop-in for models.layers.decode_attention on TPU."""
    B, _, Hq, D = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    S = k_cache.shape[1]
    bs = min(block_s, S)
    while S % bs:
        bs //= 2
    qr = q.reshape(B, Hkv, G, D)
    lng = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
    out = flash_decode(qr, k_cache, v_cache, lng, block_s=max(bs, 1),
                       interpret=interpret)
    return out.reshape(B, 1, Hq, D)
