"""Pallas TPU kernel: single-token (decode) attention over a long KV cache.

Split-K/flash-decoding style: grid = (batch, kv_heads, S/block_s); each
step loads a (block_s, D) KV tile into VMEM, updates the online-softmax
running (m, l, acc) scratch, and the final step normalizes into the output
block.  ``length`` is scalar-prefetched to mask the tail.  Block sizes are
MXU-aligned: D padded to 128 lanes, block_s a multiple of 8 sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_s: int, scale: float):
    b = pl.program_id(0)
    s = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)       # (block_s, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
    pos = s * block_s + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(pos < len_ref[b], logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
    p = jnp.exp(logits - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_new = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(s == ns - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, length: jax.Array,
                 block_s: int = 256, interpret: bool = True) -> jax.Array:
    """q: (B, Hkv, G, D); k/v: (B, S, Hkv, D); length: (B,) int32.

    Returns (B, Hkv, G, D) attention output in q.dtype."""
    B, Hkv, G, D = q.shape
    S = k.shape[1]
    assert S % block_s == 0, (S, block_s)
    scale = 1.0 / (D ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, S // block_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s, L: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, s, L: (b, s, h, 0)),
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, s, L: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s, L: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(length.astype(jnp.int32), q, k, v)
