"""Oracle: the DCQCN update from repro.core.cc applied to tiled state."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.cc import Signals, make_dcqcn


def dcqcn_update_tiled_ref(state2d, ecn2d, line2d, t, params):
    pk = dict(params)
    pol = make_dcqcn(g=pk["g"], rai_frac=pk["rai_frac"], rhai_frac=pk["rhai_frac"],
                     timer=pk["timer"], cut_gap=pk["cut_gap"],
                     fast_rounds=int(pk["fast_rounds"]), hai_after=int(pk["hai_after"]),
                     ecn_thresh=pk["ecn_thresh"], mss=pk["mss"])
    rc, rt, alpha, t_cut, t_inc, t_alpha, cnt, jit = [a.reshape(-1) for a in state2d]
    st = {"rc": rc, "rt": rt, "alpha": alpha, "jit": jit, "t_cut": t_cut,
          "t_inc": t_inc, "t_alpha": t_alpha, "inc_count": cnt}
    sig = Signals(ecn=ecn2d.reshape(-1), rtt=jnp.zeros_like(rc),
                  util=jnp.zeros_like(rc), t=jnp.asarray(t, jnp.float32),
                  dt=jnp.float32(1e-6), line=line2d.reshape(-1),
                  base_rtt=jnp.zeros_like(rc))
    st2, rate, _ = pol.update(pol.params, st, sig)
    shape = state2d[0].shape
    order = ("rc", "rt", "alpha", "t_cut", "t_inc", "t_alpha", "inc_count")
    return tuple(st2[k].reshape(shape) for k in order)
