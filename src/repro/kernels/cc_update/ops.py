"""Wrapper: flat per-flow DCQCN state -> tiled Pallas update -> flat."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.cc_update.cc_update import dcqcn_update_tiled

ORDER = ("rc", "rt", "alpha", "t_cut", "t_inc", "t_alpha", "inc_count", "jit")


def _tile(x, n_pad):
    return jnp.pad(x, (0, n_pad)).reshape(-1, 128)


def dcqcn_update(state: dict, ecn: jax.Array, line: jax.Array, t,
                 params: dict, interpret: bool | None = None) -> dict:
    """state: dict of (F,) float32 (cc.make_dcqcn layout).  Returns the
    updated dict (rate == updated rc).  ``interpret=None`` auto-detects:
    compiled Mosaic on TPU, interpret mode elsewhere."""
    interpret = default_interpret(interpret)
    F = ecn.shape[0]
    n_pad = (-F) % 128
    tiles = tuple(_tile(state[k].astype(jnp.float32), n_pad) for k in ORDER)
    ecn2d = _tile(ecn.astype(jnp.float32), n_pad)
    line2d = _tile(line.astype(jnp.float32), n_pad)
    pk = tuple(sorted({**params}.items()))
    outs = dcqcn_update_tiled(tiles, ecn2d, line2d, jnp.asarray(t, jnp.float32),
                              pk, interpret=interpret)
    new = {k: o.reshape(-1)[:F] for k, o in zip(ORDER[:7], outs)}
    new["jit"] = state["jit"]
    return new
