"""Pallas TPU kernel: fused DCQCN per-flow state update.

The fluid simulator's arithmetic hot-spot when sweeping CC configurations
on-TPU: 8 state arrays + 1 signal array -> 8 outputs, all elementwise over
flows.  Flows are tiled (8, 128) (sublane x lane) so a 65k-flow schedule is
64 grid steps of one fused VPU pass each — one HBM round-trip instead of
the ~30 XLA would need for the unfused update chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(rc_ref, rt_ref, alpha_ref, tcut_ref, tinc_ref, talpha_ref,
            cnt_ref, jit_ref, ecn_ref, line_ref, t_ref,
            o_rc, o_rt, o_alpha, o_tcut, o_tinc, o_talpha, o_cnt,
            *, g, rai_frac, rhai_frac, timer, cut_gap, fast_rounds,
            hai_after, ecn_thresh, mss):
    t = t_ref[0, 0]
    rc, rt, alpha = rc_ref[...], rt_ref[...], alpha_ref[...]
    t_cut, t_inc, t_alpha = tcut_ref[...], tinc_ref[...], talpha_ref[...]
    inc_count, jit, ecn, line = cnt_ref[...], jit_ref[...], ecn_ref[...], line_ref[...]

    pkts = rc * cut_gap / mss
    p_cnp = 1.0 - jnp.exp(-pkts * ecn)
    cong = p_cnp > ecn_thresh
    docut = cong & ((t - t_cut) >= cut_gap * jit)
    rt = jnp.where(docut, rc, rt)
    rc = jnp.where(docut, rc * (1 - alpha / 2 * p_cnp), rc)
    alpha = jnp.where(docut, (1 - g * p_cnp) * alpha + g * p_cnp, alpha)
    t_cut = jnp.where(docut, t, t_cut)
    inc_count = jnp.where(docut, 0.0, inc_count)
    t_inc = jnp.where(docut, t, t_inc)

    dodec = (~cong) & ((t - t_alpha) >= timer * jit)
    alpha = jnp.where(dodec, (1 - g) * alpha, alpha)
    t_alpha = jnp.where(dodec | docut, t, t_alpha)

    doinc = (t - t_inc) >= timer * jit
    inc_count = jnp.where(doinc, inc_count + 1, inc_count)
    additive = inc_count > fast_rounds
    hyper = inc_count > fast_rounds + hai_after
    bump = jnp.where(hyper, rhai_frac, rai_frac) * line
    rt = jnp.where(doinc & additive, rt + bump, rt)
    rc = jnp.where(doinc, 0.5 * (rt + rc), rc)
    t_inc = jnp.where(doinc, t, t_inc)

    rc = jnp.clip(rc, 0.001 * line, line)
    rt = jnp.clip(rt, 0.001 * line, line)

    o_rc[...], o_rt[...], o_alpha[...] = rc, rt, alpha
    o_tcut[...], o_tinc[...], o_talpha[...], o_cnt[...] = t_cut, t_inc, t_alpha, inc_count


@functools.partial(jax.jit, static_argnames=("params", "interpret"))
def dcqcn_update_tiled(state2d: tuple, ecn2d: jax.Array, line2d: jax.Array,
                       t: jax.Array, params: tuple, interpret: bool = True):
    """state2d: 8-tuple of (N8, 128) float32 arrays
    (rc, rt, alpha, t_cut, t_inc, t_alpha, inc_count, jit); returns the
    7 updated state arrays (jit is static)."""
    pk = dict(params)
    N8 = ecn2d.shape[0]
    bs = min(8, N8)
    spec = pl.BlockSpec((bs, 128), lambda i: (i, 0))
    tspec = pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM)
    out_shape = [jax.ShapeDtypeStruct((N8, 128), jnp.float32)] * 7
    outs = pl.pallas_call(
        functools.partial(_kernel, **pk),
        grid=(N8 // bs,),
        in_specs=[spec] * 10 + [tspec],
        out_specs=[spec] * 7,
        out_shape=out_shape,
        interpret=interpret,
    )(*state2d, ecn2d, line2d, t.reshape(1, 1))
    return outs
