"""Pure-jnp oracle for the embedding-bag kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_rows_ref(table2d: jax.Array, rows: jax.Array) -> jax.Array:
    """(R, Dp), (NB, P) -> (NB, Dp) float32 sum-pool."""
    gathered = table2d[rows]              # (NB, P, Dp)
    return gathered.astype(jnp.float32).sum(axis=1)


def embedding_bag_stacked_ref(tables: jax.Array, idx: jax.Array) -> jax.Array:
    """tables (T, R, D), idx (B, T, P) -> (B, T, D) in tables.dtype."""
    def per_table(tab, ix):
        return tab[ix].astype(jnp.float32).sum(axis=1)
    pooled = jax.vmap(per_table, in_axes=(0, 1), out_axes=1)(tables, idx)
    return pooled.astype(tables.dtype)
