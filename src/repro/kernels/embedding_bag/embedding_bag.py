"""Pallas TPU kernel: multi-hot embedding-bag sum-pooling (DLRM hot-spot).

TPU adaptation (DESIGN.md §6): the GPU version is a warp-per-bag gather;
on TPU the idiom is *scalar-prefetch-driven DMA* — the multi-hot indices
are prefetched as scalars, and each grid step's BlockSpec index_map selects
the (1, D) table row to DMA from HBM into VMEM, accumulating into the
revisited output block.  grid = (bags, pooling); rows land MXU-aligned by
padding D to a lane multiple (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, table_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += table_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_rows(table2d: jax.Array, rows: jax.Array,
                       interpret: bool = True) -> jax.Array:
    """table2d: (R, Dp) with Dp % 128 == 0; rows: (NB, P) int32.

    Returns (NB, Dp) float32 sum-pooled bags."""
    NB, P = rows.shape
    _, Dp = table2d.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(NB, P),
        in_specs=[
            pl.BlockSpec((1, Dp), lambda i, j, idx_ref: (idx_ref[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, Dp), lambda i, j, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((NB, Dp), jnp.float32),
        interpret=interpret,
    )(rows, table2d)
