"""jit'd public wrapper: stacked DLRM tables -> pooled bags via Pallas."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import embedding_bag_rows


def embedding_bag_stacked(tables: jax.Array, idx: jax.Array,
                          interpret: bool = True) -> jax.Array:
    """tables (T, R, D), idx (B, T, P) int32 -> (B, T, D) in tables.dtype.

    Flattens the stacked tables to one (T*R, Dp) row space (row id =
    t*R + idx), pads D to a 128-lane multiple, and runs the
    scalar-prefetch gather-accumulate kernel over (B*T, P)."""
    T, R, D = tables.shape
    B = idx.shape[0]
    P = idx.shape[2]
    Dp = max(128, ((D + 127) // 128) * 128)
    tab2d = tables.reshape(T * R, D)
    if Dp != D:
        tab2d = jnp.pad(tab2d, ((0, 0), (0, Dp - D)))
    # bag (b, t) -> rows t*R + idx[b, t, :]
    rows = (idx + (jnp.arange(T, dtype=idx.dtype) * R)[None, :, None])
    rows = rows.reshape(B * T, P).astype(jnp.int32)
    out = embedding_bag_rows(tab2d, rows, interpret=interpret)
    return out[:, :D].reshape(B, T, D).astype(tables.dtype)
