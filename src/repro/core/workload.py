"""Workload layer: DLRM training iteration -> flow schedule with
compute/comm dependencies (ASTRA-Sim workload layer analog).

The paper's DLRM iteration (Fig 10, §IV-D):
  fwd:  bottom-MLP compute  ||  embedding lookup -> All-To-All (fwd half)
        -> interaction + top-MLP compute
  bwd:  top-MLP backprop -> All-To-All (bwd half) || bottom-MLP backprop
        -> per-chunk All-Reduce of MLP grads (2D or 1D), overlapping bwd
  Totals per iteration: 109.5 MB All-Reduce + 8 MB All-To-All.

Compute segment durations come from a V100 profile table (the paper uses
NVIDIA V100 profiling); they are constants here, and the *exposed*
communication =  iteration_time - total_compute  is the reported metric.
"""
from __future__ import annotations

import dataclasses


from repro.core.collectives import Schedule, ScheduleBuilder, _direct_phase
from repro.core.engine import EngineConfig, simulate
from repro.core.scenario import ScenarioSpec
from repro.core.topology import Topology


# V100-profile compute constants (s) for the paper's DLRM (Table II) with
# per-GPU batch ~256.  Sources: MLPerf DLRM v0.7 V100 per-layer timings,
# scaled to the paper's layer sizes; recorded here as the workload model.
@dataclasses.dataclass(frozen=True)
class DLRMComputeProfile:
    bot_mlp_fwd: float = 350e-6
    emb_lookup: float = 80e-6
    interact_top_fwd: float = 800e-6
    top_bwd: float = 1400e-6
    bot_bwd: float = 700e-6
    opt_update: float = 250e-6

    @property
    def total(self) -> float:
        return (self.bot_mlp_fwd + self.emb_lookup + self.interact_top_fwd
                + self.top_bwd + self.bot_bwd + self.opt_update)


@dataclasses.dataclass(frozen=True)
class DLRMCommSpec:
    allreduce_bytes: float = 109.5 * 1024 * 1024
    alltoall_fwd_bytes: float = 4 * 1024 * 1024
    alltoall_bwd_bytes: float = 4 * 1024 * 1024
    n_chunks: int = 4
    allreduce_algo: str = "2d"    # "1d" | "2d"


def build_dlrm_iteration(topo: Topology, gpus: list,
                         prof: DLRMComputeProfile = DLRMComputeProfile(),
                         comm: DLRMCommSpec = DLRMCommSpec()) -> Schedule:
    """One DLRM training iteration as a dependency-tagged flow schedule."""
    b = ScheduleBuilder(topo)

    # ---- forward ----------------------------------------------------------
    # embedding lookup finishes at emb_lookup; fwd A2A starts then
    g_emb = b.new_group("emb_done")
    b.add_marker(g_emb, dep=-1, delay=prof.emb_lookup)
    a2a_f = _add_a2a(b, gpus, comm.alltoall_fwd_bytes, comm.n_chunks,
                     dep=g_emb, tag="a2a_fwd")
    # bottom MLP fwd runs concurrently; top MLP needs both
    g_bot = b.new_group("bot_fwd_done")
    b.add_marker(g_bot, dep=-1, delay=prof.bot_mlp_fwd)
    g_top = b.new_group("top_fwd_done")
    b.add_marker(g_top, dep=a2a_f, delay=prof.interact_top_fwd)

    # ---- backward ---------------------------------------------------------
    g_topb = b.new_group("top_bwd_done")
    b.add_marker(g_topb, dep=g_top, delay=prof.top_bwd)
    _add_a2a(b, gpus, comm.alltoall_bwd_bytes, comm.n_chunks,
             dep=g_topb, tag="a2a_bwd")
    g_botb = b.new_group("bot_bwd_done")
    b.add_marker(g_botb, dep=g_topb, delay=prof.bot_bwd)

    # ---- gradient all-reduce (per chunk, overlapping bwd) ------------------
    if comm.allreduce_algo == "2d":
        _add_ar2d(b, topo, gpus, comm.allreduce_bytes, comm.n_chunks, dep=g_topb)
    else:
        _add_ar1d(b, gpus, comm.allreduce_bytes, comm.n_chunks, dep=g_topb)
    return b.build()


def _add_a2a(b, gpus, total, n_chunks, dep, tag):
    P = len(gpus)
    per_pair = total / n_chunks / P
    prev = dep
    for c in range(n_chunks):
        g = b.new_group(f"{tag}_c{c}")
        _direct_phase(b, gpus, per_pair, g, prev, 0.0, salt=hash(tag) % 65536 + c * 104729)
        prev = g
    # umbrella group: completion of the last chunk == collective done
    return prev


def _add_ar1d(b, gpus, total, n_chunks, dep):
    P = len(gpus)
    seg = total / n_chunks / P
    prev_rs = dep
    for c in range(n_chunks):
        rs = b.new_group(f"ar_c{c}_rs")
        _direct_phase(b, gpus, seg, rs, prev_rs, 0.0, salt=c * 7919)
        ag = b.new_group(f"ar_c{c}_ag")
        _direct_phase(b, gpus, seg, ag, rs, 0.0, salt=c * 7919 + 31)
        prev_rs = rs
    return ag


def _add_ar2d(b, topo, gpus, total, n_chunks, dep):
    gpn = topo.meta.get("gpus_per_node", 8)
    nodes: dict = {}
    for g in gpus:
        nodes.setdefault(g // gpn, []).append(g)
    node_list = sorted(nodes)
    n_nodes = len(node_list)
    chunk = total / n_chunks
    prev1 = dep
    last = None
    for c in range(n_chunks):
        g1 = b.new_group(f"ar_c{c}_rs_local")
        for node in node_list:
            _direct_phase(b, nodes[node], chunk / gpn, g1, prev1, 0.0,
                          salt=c * 7919 + node)
        g2 = b.new_group(f"ar_c{c}_rs_xnode")
        for r in range(gpn):
            members = [nodes[n][r] for n in node_list]
            _direct_phase(b, members, chunk / (gpn * n_nodes), g2, g1, 0.0,
                          salt=c * 7919 + 101 + r)
        g3 = b.new_group(f"ar_c{c}_ag_xnode")
        for r in range(gpn):
            members = [nodes[n][r] for n in node_list]
            _direct_phase(b, members, chunk / (gpn * n_nodes), g3, g2, 0.0,
                          salt=c * 7919 + 211 + r)
        g4 = b.new_group(f"ar_c{c}_ag_local")
        for node in node_list:
            _direct_phase(b, nodes[node], chunk / gpn, g4, g3, 0.0,
                          salt=c * 7919 + 307 + node)
        prev1 = g1
        last = g4
    return last


@dataclasses.dataclass(frozen=True)
class DLRMIterationSpec:
    """Scenario workload: one DLRM training iteration (compute markers +
    A2A halves + per-chunk gradient All-Reduce)."""
    prof: DLRMComputeProfile = DLRMComputeProfile()
    comm: DLRMCommSpec = DLRMCommSpec()
    gpus: tuple | None = None      # None -> every fabric GPU

    def build_schedule(self, topo: Topology) -> Schedule:
        gpus = (list(self.gpus) if self.gpus is not None
                else list(range(topo.n_gpus)))
        return build_dlrm_iteration(topo, gpus, self.prof, self.comm)


@dataclasses.dataclass
class IterationReport:
    iteration_time: float
    total_compute: float
    exposed_comm: float
    pfc_pauses: int
    policy: str
    finished: bool


def simulate_dlrm_policies(topo: Topology, gpus: list, policies=None,
                           prof: DLRMComputeProfile = DLRMComputeProfile(),
                           comm: DLRMCommSpec = DLRMCommSpec(),
                           cfg: EngineConfig = EngineConfig(dt=2e-6),
                           runner=None,
                           batched: bool | None = None) -> list[IterationReport]:
    """The Fig-10 per-policy loop as ONE vmapped policy-axis dispatch:
    every CC policy simulates the same DLRM iteration in a single compiled
    call (``SweepRunner.run_policy_axis``).  ``batched=None`` defers to
    ``SweepRunner.policy_axis_pays_off`` (serial fallback on CPU, same
    reports either way)."""
    from repro.core import cc as cc_mod
    from repro.core.sweep import SweepRunner
    runner = runner or SweepRunner(cfg)
    sched = build_dlrm_iteration(topo, gpus, prof, comm)
    policies = tuple(policies or cc_mod.ALL_POLICIES)
    if batched is None:
        batched = runner.policy_axis_pays_off()
    if not batched:
        from repro.core.cc import get_policy
        return [simulate_dlrm_iteration(
                    topo, gpus, get_policy(p) if isinstance(p, str) else p,
                    prof, comm, cfg=cfg, runner=runner)
                for p in policies]
    batch = runner.run_policy_axis(topo, sched, policies, cfg=cfg)
    out = []
    for i in range(batch.n):
        iter_time = float(batch.completion_time[i]) + prof.opt_update
        out.append(IterationReport(
            iteration_time=iter_time,
            total_compute=prof.total,
            exposed_comm=max(iter_time - prof.total, 0.0),
            pfc_pauses=int(batch.pause_count[i].sum()),
            policy=batch.policy_of(i),
            finished=bool(batch.finished[i]),
        ))
    return out


def simulate_dlrm_iteration(topo: Topology, gpus: list, policy,
                            prof: DLRMComputeProfile = DLRMComputeProfile(),
                            comm: DLRMCommSpec = DLRMCommSpec(),
                            cfg: EngineConfig = EngineConfig(dt=2e-6),
                            runner=None) -> IterationReport:
    """Pass a ``repro.core.sweep.SweepRunner`` to reuse compiled engines
    across the per-policy / per-algo loops of Figs 10-11."""
    spec = ScenarioSpec(fabric=topo, policy=policy,
                        workload=DLRMIterationSpec(prof, comm, tuple(gpus)))
    if runner is not None:
        res = runner.run_spec(spec, cfg=cfg)
    else:
        topo, sched, policy = spec.build()
        res = simulate(topo, sched, policy, cfg)
    # iteration ends when every flow (incl. compute markers) is done, plus
    # the optimizer update after the last gradient arrives
    iter_time = res.completion_time + prof.opt_update
    total_compute = prof.total
    return IterationReport(
        iteration_time=iter_time,
        total_compute=total_compute,
        exposed_comm=max(iter_time - total_compute, 0.0),
        pfc_pauses=int(res.pause_count.sum()),
        policy=policy.name,
        finished=res.finished,
    )
