"""Network layer: fixed-timestep, fully-vectorized fluid-flow simulator.

JAX/TPU-native adaptation of the paper's NS3 packet-level layer (DESIGN.md
§2): per-flow/per-link flat arrays stepped inside one ``lax.scan``.

Per step Δt:
  1. delayed signals (ECN fraction, RTT, HPCC INT utilisation) read from a
     per-link history ring at t - base_rtt(flow)
  2. CC policy update -> per-flow rate / window
  3. paced, window-gated injection into the source NIC egress queue
  4. hop-ordered fluid forwarding with per-link capacity accounting and
     proportional backlog drain (per-flow per-hop backlog => exact byte
     conservation)
  5. PFC: per-switch buffer hysteresis (X_OFF/X_ON) pauses all upstream
     links into that switch; pause transitions are counted (Fig 9 metric)
  6. dependency groups: flows start when their dep group completes (+ a
     compute delay), giving chunk pipelining and workload DAGs

The engine is differentiable w.r.t. CC policy parameters: `soft_cost`
integrates the undelivered fraction over time (see core/autotune.py).

Hot path
--------
All per-link reductions (hop demand, queue occupancy, PFC port pressure,
group completion counts) go through *static gather plans* built once in
``_prep``: flow->link membership is known ahead of time, so each reduction
is a padded gather + row-sum over a precomputed ``(segments, Cmax)`` index
matrix instead of an XLA scatter-add (an order of magnitude faster on CPU;
pathological fan-ins fall back to scatter, chosen statically per scenario).
The feedback history ring is sized to the actual maximum ``delay_steps``
(next power of two) rather than a fixed ``cfg.hist`` slots.

Early-exit semantics
--------------------
``Simulator.run`` integrates ``max_steps * (max_extends + 1)`` total steps,
but inside one jitted call: a ``lax.while_loop`` over ``cfg.chunk_steps``-
sized ``lax.scan`` chunks stops as soon as every flow has completed, and
each step is additionally gated on ``done.all()`` via ``lax.cond`` so the
tail of the final chunk costs ~nothing.  Because finished steps are exact
no-ops, an early-exited run is *bitwise identical* to a monolithic scan of
the full step budget (``run(early_exit=False)``), and results never depend
on ``chunk_steps``.  The carry is donated to the compiled call.

The per-device queue timeline (``Results.dev_queue``, consumed only by the
Fig 5-7 style plots) is recorded every ``cfg.queue_stride`` steps, or not
at all with ``queue_stride=0`` — the recommended setting for sweeps.

Dynamic fabric parameters
-------------------------
ECN marking (kmin/kmax/pmax) and PFC thresholds (xoff/xon) are *traced*
inputs — a ``FabricParams`` pytree passed alongside ``cc_params`` — not
static config.  Leaves may be scalars or per-link-class arrays (indexed by
``topology.LINK_CLASSES``), so fabric-tuning grids vmap-batch through
``SweepRunner`` without recompiling and ``soft_cost`` differentiates
through fabric knobs as well as CC parameters.

Batched sweeps over CC parameters (vmap) and the cross-scenario compile
cache live in ``repro.core.sweep`` (``SweepRunner``); compiled step
functions here are keyed on ``(policy, cfg, static plan)`` so same-shaped
scenarios never retrace.  Declarative scenario construction
(``ScenarioSpec``) lives in ``repro.core.scenario``.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.cc import (FlowCtx, ParamSpec, Policy, Signals,
                           kernel_eligible)
from repro.core.collectives import Schedule
from repro.core.faults import (FaultSpec, LaneStatus, _as_fault,
                               classify_lane, is_faulty)
from repro.core.topology import (LINK_CLASS_ID, MAXHOP, N_LINK_CLASSES,
                                 Topology)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    dt: float = 1e-6
    max_steps: int = 20_000
    max_extends: int = 4          # extra step budget: total = max_steps*(1+extends)
    hist: int = 512               # feedback delay ring cap (steps)
    # ECN / PFC *defaults*: these scalars only seed the default
    # ``FabricParams`` (the dynamic, traced fabric knobs passed alongside
    # cc_params); the compiled step never reads them, so two configs
    # differing only here share one executable (see ``_cfg_static``)
    kmin: float = 400e3           # ECN / RED marking at switch egress queues
    kmax: float = 1600e3
    pmax: float = 0.2
    # PFC per-ingress-port hysteresis (bytes queued in the switch that
    # arrived through that port; pause is sent to that port's sender only)
    xoff: float = 1e6
    xon: float = 0.8e6
    t_base_util: float = 10e-6    # HPCC qlen->util horizon
    eps_done: float = 512.0       # completion slack (bytes)
    pause_resend: float = 5e-6    # PAUSE frame refresh while a port is paused
    # hot-path knobs (do not change simulated physics)
    chunk_steps: int = 256        # early-exit check granularity (in-jit)
    queue_stride: int = 1         # record dev_queue every k steps; 0 = off
    # step backend: "auto" resolves per jax.default_backend() — the fused
    # Pallas engine-step kernels (repro.kernels.engine_step) on TPU/GPU,
    # the historical jnp path elsewhere, so CPU results stay bitwise
    # identical to the engine goldens.  "pallas" forces the kernel path
    # (interpret-mode off-TPU: the CI correctness configuration); "jnp"
    # forces the reference path on any backend.
    step_impl: str = "auto"       # "auto" | "jnp" | "pallas"
    # run-health detection (observers only; never change simulated physics)
    deadlock_check_every: int = 64   # pause-cycle check cadence (steps)
    storm_frac: float = 0.5          # pause storm: fraction of ports paused
    storm_steps: int = 50            # ... for this many consecutive steps


_FABRIC_DEFAULTS = dict(kmin=400e3, kmax=1600e3, pmax=0.2, xoff=1e6, xon=0.8e6)

# declarative search spaces for the fabric knobs — same ParamSpec currency
# as the CC policies, consumed by ``autotune`` (scale + bounds projection)
# and ``sweep.grid_from_spec``
FABRIC_PARAM_SPECS = {
    "kmin": ParamSpec(_FABRIC_DEFAULTS["kmin"], lo=1e3, hi=64e6, scale="log"),
    "kmax": ParamSpec(_FABRIC_DEFAULTS["kmax"], lo=4e3, hi=256e6, scale="log"),
    "pmax": ParamSpec(_FABRIC_DEFAULTS["pmax"], lo=0.01, hi=1.0,
                      scale="linear"),
    "xoff": ParamSpec(_FABRIC_DEFAULTS["xoff"], lo=10e3, hi=64e6,
                      scale="log"),
    "xon": ParamSpec(_FABRIC_DEFAULTS["xon"], lo=10e3, hi=64e6, scale="log"),
}


@dataclasses.dataclass(frozen=True)
class FabricParams:
    """Dynamic fabric tuning knobs: a pytree traced alongside ``cc_params``.

    Each leaf is either a scalar (uniform fabric) or a per-link-class array
    of shape ``(N_LINK_CLASSES,)`` indexed by ``topology.LINK_CLASSES``, so
    e.g. spine downlinks can mark earlier than ToR downlinks.  Leaves ride
    through jit/vmap/grad: fabric-parameter grids batch through
    ``SweepRunner`` without recompiling, and ``soft_cost`` differentiates
    through them.  Scalar defaults reproduce the historical
    ``EngineConfig`` behavior bit-for-bit.
    """
    kmin: object = _FABRIC_DEFAULTS["kmin"]   # ECN marking ramp start (bytes)
    kmax: object = _FABRIC_DEFAULTS["kmax"]   # ECN marking ramp end (bytes)
    pmax: object = _FABRIC_DEFAULTS["pmax"]   # max marking probability
    xoff: object = _FABRIC_DEFAULTS["xoff"]   # PFC pause threshold (bytes)
    xon: object = _FABRIC_DEFAULTS["xon"]     # PFC resume threshold (bytes)

    FIELDS = ("kmin", "kmax", "pmax", "xoff", "xon")

    @classmethod
    def from_config(cls, cfg: EngineConfig) -> "FabricParams":
        return cls(kmin=cfg.kmin, kmax=cfg.kmax, pmax=cfg.pmax,
                   xoff=cfg.xoff, xon=cfg.xon)

    @classmethod
    def check_fields(cls, keys):
        """Reject names that are not FabricParams fields."""
        unknown = set(keys) - set(cls.FIELDS)
        if unknown:
            raise ValueError(f"unknown fabric params {sorted(unknown)}; "
                             f"known: {list(cls.FIELDS)}")

    def replace(self, **kw) -> "FabricParams":
        return dataclasses.replace(self, **kw)

    def with_class(self, **field_overrides) -> "FabricParams":
        """Per-link-class overrides: ``fab.with_class(kmin={"spine_down":
        100e3})`` expands ``kmin`` to a per-class array with the named
        classes replaced and every other class at this instance's value."""
        out = {}
        for field, overrides in field_overrides.items():
            base = np.broadcast_to(
                np.asarray(getattr(self, field), np.float32),
                (N_LINK_CLASSES,)).copy()
            for cls_name, v in overrides.items():
                base[LINK_CLASS_ID[cls_name]] = v
            out[field] = base
        return dataclasses.replace(self, **out)


jax.tree_util.register_dataclass(FabricParams,
                                 data_fields=FabricParams.FIELDS,
                                 meta_fields=())


def _as_fabric(fabric_params, cfg: EngineConfig) -> FabricParams:
    return (FabricParams.from_config(cfg) if fabric_params is None
            else fabric_params)


def _per_class(v):
    """Broadcast a FabricParams leaf to one value per link class."""
    return jnp.broadcast_to(jnp.asarray(v, jnp.float32), (N_LINK_CLASSES,))


def resolve_step_impl(cfg: EngineConfig) -> str:
    """Backend dispatch for the engine step: "auto" picks the fused Pallas
    kernels on accelerator backends and the jnp reference path on CPU (so
    the default path reproduces the engine goldens bitwise there)."""
    impl = cfg.step_impl
    if impl == "auto":
        return "pallas" if jax.default_backend() in ("tpu", "gpu") else "jnp"
    if impl not in ("jnp", "pallas"):
        raise ValueError(f"step_impl must be 'auto', 'jnp' or 'pallas', "
                         f"got {impl!r}")
    return impl


def _cfg_static(cfg: EngineConfig) -> EngineConfig:
    """The compile-cache view of a config: fabric scalars are dynamic
    (delivered via FabricParams), so they are normalized out of the key;
    ``step_impl`` is resolved so "auto" shares the executable of the
    backend it resolves to."""
    return dataclasses.replace(cfg, step_impl=resolve_step_impl(cfg),
                               **_FABRIC_DEFAULTS)


@dataclasses.dataclass
class Results:
    finished: bool
    completion_time: float        # max flow finish (s)
    t_finish: np.ndarray          # (F,)
    group_time: np.ndarray        # (G,)
    group_names: list
    pause_count: np.ndarray       # (D,) PFC pause transitions per device
    dev_queue: np.ndarray         # (T//queue_stride, D) queue-bytes timeline
    dt: float
    delivered: np.ndarray
    soft_cost: float
    meta: dict
    # run health (observers; see EngineConfig deadlock/storm knobs)
    deadlocked: bool = False      # a PFC pause-graph cycle was detected
    deadlock_step: int = -1       # first step the cycle was seen (-1 = never)
    storm_step: int = -1          # first step a pause storm was sustained
    diverged: bool = False        # non-finite state; lane frozen at detection
    extend_exhausted: bool = False  # step budget ran out before completion
    lost: np.ndarray | None = None  # (F,) bytes dropped in-network (lossy mode)

    @property
    def status(self) -> LaneStatus:
        """Typed run-health verdict (``faults.LaneStatus``); the serial
        counterpart of ``BatchResults.lane_status()``."""
        return classify_lane(self.diverged, self.deadlocked, self.finished)


# ---------------------------------------------------------------------------
# static gather plans (scatter-free segment reductions)
# ---------------------------------------------------------------------------

def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


# single-level padded-gather width cap: segments with more members than
# this use the two-level split-row plan so one hot port (e.g. a full-fabric
# incast) cannot inflate the gather to n_out * max_count slots
_SPLIT_C = 64


def _padded_rows(kept_ids, kept_pos, counts, n_out, n_in, width):
    """(n_out, width) index matrix; slot ``n_in`` means "+0" (OOB fill)."""
    idx = np.full((n_out, width), n_in, np.int64)
    order = np.argsort(kept_ids, kind="stable")
    sid = kept_ids[order]
    starts = np.concatenate([[0], np.cumsum(counts)])
    slot = np.arange(len(sid)) - starts[sid]
    idx[sid, slot] = kept_pos[order]
    return idx


def _reduce_plan(ids: np.ndarray, n_in: int, n_out: int,
                 drop: np.ndarray | None = None):
    """Build a static plan for ``out[s] = sum(vals[ids == s])``.

    Entries with ``drop`` True (provably-zero contributions: padding flows,
    unused hop slots) are excluded.  Returns ``(arrays, strategy)`` where
    ``strategy`` is hashable and ``arrays`` ride along in ``pp``.

    Three strategies, chosen statically from the (known) fan-in histogram:
      empty    no live entries — the reduction is identically zero
      gather   (n_out, C) padded gather + row sum, C = max segment size
      gather2  split-row: each segment padded to a multiple of _SPLIT_C,
               one flat gather + block sum, then a tiny second-level
               padded gather over per-block partial sums
    """
    ids = np.asarray(ids, np.int64).reshape(-1)
    keep = np.ones(ids.shape, bool) if drop is None else ~np.asarray(drop).reshape(-1)
    kept_ids = ids[keep]
    kept_pos = np.nonzero(keep)[0]
    if kept_ids.size == 0:
        return {}, ("empty", n_out)
    counts = np.bincount(kept_ids, minlength=n_out)
    C = _next_pow2(int(counts.max()))
    if C <= _SPLIT_C:
        idx = _padded_rows(kept_ids, kept_pos, counts, n_out, n_in, C)
        return {"idx": jnp.asarray(idx.reshape(-1), jnp.int32)}, \
            ("gather", n_out, C)
    # split-row: block-align each segment to _SPLIT_C-wide sub-rows
    nblk = -(-counts // _SPLIT_C)                  # ceil; 0 for empty segments
    blk_start = np.concatenate([[0], np.cumsum(nblk)])
    n_blocks = int(blk_start[-1])
    perm = np.full(n_blocks * _SPLIT_C, n_in, np.int64)
    order = np.argsort(kept_ids, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)])
    for s in np.nonzero(counts)[0]:
        lo = blk_start[s] * _SPLIT_C
        perm[lo:lo + counts[s]] = kept_pos[order[starts[s]:starts[s] + counts[s]]]
    C2 = _next_pow2(int(nblk.max()))
    bidx = np.full((n_out, C2), n_blocks, np.int64)
    for s in np.nonzero(nblk)[0]:
        bidx[s, :nblk[s]] = np.arange(blk_start[s], blk_start[s + 1])
    return {"perm": jnp.asarray(perm, jnp.int32),
            "bidx": jnp.asarray(bidx.reshape(-1), jnp.int32)}, \
        ("gather2", n_out, n_blocks, C2)


def _reduce(strategy, arrs, vals):
    """Apply a ``_reduce_plan``: (n_in,) vals -> (n_out,) segment sums."""
    kind = strategy[0]
    if kind == "empty":
        return jnp.zeros((strategy[1],), vals.dtype)
    if kind == "gather":
        _, n_out, C = strategy
        rows = vals.at[arrs["idx"]].get(mode="fill", fill_value=0.0)
        return rows.reshape(n_out, C).sum(axis=1)
    _, n_out, n_blocks, C2 = strategy
    sub = vals.at[arrs["perm"]].get(mode="fill", fill_value=0.0)
    bsum = sub.reshape(n_blocks, _SPLIT_C).sum(axis=1)
    rows = bsum.at[arrs["bidx"]].get(mode="fill", fill_value=0.0)
    return rows.reshape(n_out, C2).sum(axis=1)


@dataclasses.dataclass(frozen=True)
class _Plan:
    """Hashable static description of one prepared scenario.

    Everything shape- or strategy-like lives here (part of the compile
    cache key); everything array-like lives in ``pp`` (traced, so two
    scenarios with equal plans share one compiled executable).
    """
    n_flows: int                  # real flows (pre-padding)
    n_flows_pad: int
    n_groups: int
    n_groups_pad: int
    n_links: int
    n_dev: int
    ring: int                     # feedback history slots (pow2)
    hop: tuple                    # per-hop demand reduction strategies
    qlink: tuple
    qport: tuple
    group: tuple
    pause: tuple
    qdev: tuple


def _prep(topo: Topology, sched: Schedule, cfg: EngineConfig,
          pad_flows: int | None = None, pad_groups: int | None = None):
    """Precompute static per-flow/per-link arrays + gather plans.

    ``pad_flows`` / ``pad_groups`` pad the flow and group axes with inert
    entries (done at t=0, zero bytes, null links) so that differently-sized
    schedules can share one compiled executable (shape-bucket padding; see
    ``repro.core.sweep``).  Padding never changes simulated physics: padded
    flows are excluded from every reduction plan and start out done.
    """
    Lk = topo.n_links
    F = sched.n_flows
    G = sched.n_groups
    Fp = max(pad_flows or F, F)
    Gp = max(pad_groups or G, G)

    path = np.where(sched.path < 0, Lk, sched.path).astype(np.int32)
    cap = np.concatenate([topo.cap, [1e18]]).astype(np.float32)
    lat = np.concatenate([topo.lat, [0.0]]).astype(np.float32)
    ecn_on = np.concatenate([topo.ecn_on, [False]])
    dst_dev = np.concatenate([topo.dst_dev, [topo.n_devices]]).astype(np.int32)
    # fabric-link class per link; the null link (Lk) never marks ECN and
    # never pauses, so its class is irrelevant — use 0
    link_class = np.concatenate([topo.link_class, [0]]).astype(np.int32)

    # ingress map: backlog at hop h arrived via link path[:, h-1] (h >= 1);
    # hop-0 backlog is the host's own send queue (never paused by PFC)
    ingress = np.full_like(path, Lk)
    ingress[:, 1:] = np.where(sched.path[:, 1:] >= 0, path[:, :-1], Lk)
    # a port can be paused only if its receiver is a PFC-capable switch
    dev_sw_ext = np.concatenate([topo.dev_is_switch, [False]])
    fabric_ext = np.concatenate([topo.fabric, [False]])
    can_pause = dev_sw_ext[dst_dev] & fabric_ext
    # pause-cycle (deadlock) wait-for graph support: only switch->switch
    # fabric links can participate in a PFC cycle (hosts do not forward)
    sw_sw = (topo.dev_is_switch[topo.src_dev]
             & topo.dev_is_switch[topo.dst_dev] & topo.fabric)

    # static fan-in: CONCURRENT flows sharing each flow's most-contended
    # link.  Deterministic schedules serialize phases via dep groups, so
    # only same-group flows contend — exactly the knowledge the paper says
    # an optimized CC should exploit (§IV-E).
    link_load = np.zeros(Lk + 1, np.float64)
    for g in range(max(G, 1)):
        in_g = (sched.group == g) & (sched.size > 0)
        if not in_g.any():
            continue
        load_g = np.zeros(Lk + 1, np.float64)
        for h in range(path.shape[1]):
            np.add.at(load_g, path[in_g, h], 1.0)
        link_load = np.maximum(link_load, load_g)
    link_load[Lk] = 1.0
    fanin = np.ones(F, np.float64)
    for h in range(path.shape[1]):
        valid = sched.path[:, h] >= 0
        fanin = np.maximum(fanin, np.where(valid, link_load[path[:, h]], 1.0))

    hopmask = (sched.path >= 0)
    base_rtt = 2.0 * (lat[path] * hopmask).sum(1)
    # serialization/propagation floor so zero-latency markers behave
    base_rtt = np.maximum(base_rtt, 1e-7).astype(np.float32)
    delay_steps = np.clip(np.round(base_rtt / cfg.dt), 1, cfg.hist - 1).astype(np.int32)
    first = path[:, 0]
    line = cap[first].astype(np.float32)
    bdp = (line * base_rtt).astype(np.float32)
    gsize = np.zeros(G, np.float32)
    np.add.at(gsize, sched.group, 1.0)

    # ---- shape-bucket padding (inert flows/groups) ------------------------
    def fpad(a, fill):
        if Fp == a.shape[0]:
            return a
        pad = np.full((Fp - a.shape[0],) + a.shape[1:], fill, a.dtype)
        return np.concatenate([a, pad])

    active = np.zeros(Fp, bool)
    active[:F] = True
    path = fpad(path, Lk)
    ingress = fpad(ingress, Lk)
    hopmask = fpad(hopmask, False)
    n_hops = fpad(sched.n_hops.astype(np.int32), 0)
    base_rtt = fpad(base_rtt, 1e-7)
    delay_steps = fpad(delay_steps, 1)
    line = fpad(line, 1.0)
    bdp = fpad(bdp, 1.0)
    fanin = fpad(fanin.astype(np.float32), 1.0)
    size = fpad(sched.size.astype(np.float32), 0.0)
    group = fpad(sched.group.astype(np.int32), 0)
    dep = fpad(sched.dep.astype(np.int32), -1)
    sdelay = fpad(sched.delay.astype(np.float32), 0.0)
    gsize = np.concatenate([gsize, np.zeros(Gp - G, np.float32)])

    # ---- reduction plans ---------------------------------------------------
    invalid = ~hopmask                     # null-link slots contribute zero
    hop_arrs, hop_strats = [], []
    for h in range(MAXHOP):
        a, s = _reduce_plan(path[:, h], Fp, Lk + 1, drop=invalid[:, h])
        hop_arrs.append(a)
        hop_strats.append(s)
    ql_a, ql_s = _reduce_plan(path.reshape(-1), Fp * MAXHOP, Lk + 1,
                              drop=invalid.reshape(-1))
    qp_a, qp_s = _reduce_plan(ingress.reshape(-1), Fp * MAXHOP, Lk + 1,
                              drop=(ingress == Lk).reshape(-1))
    gr_a, gr_s = _reduce_plan(group, Fp, Gp, drop=~active)
    pa_a, pa_s = _reduce_plan(dst_dev[:Lk], Lk, topo.n_devices)
    qd_a, qd_s = _reduce_plan(topo.src_dev, Lk, topo.n_devices)

    ring = _next_pow2(int(delay_steps.max()) + 1)

    plan = _Plan(
        n_flows=F, n_flows_pad=Fp, n_groups=G, n_groups_pad=Gp,
        n_links=Lk, n_dev=topo.n_devices, ring=ring,
        hop=tuple(hop_strats), qlink=ql_s, qport=qp_s,
        group=gr_s, pause=pa_s, qdev=qd_s,
    )
    pp = dict(
        path=jnp.asarray(path), cap=jnp.asarray(cap),
        dst_dev=jnp.asarray(dst_dev), can_pause=jnp.asarray(can_pause),
        hopmask=jnp.asarray(hopmask),
        caps_path=jnp.asarray(cap[path]),
        ecn_mask=jnp.asarray((ecn_on[path] & hopmask).astype(np.float32)),
        link_class=jnp.asarray(link_class),
        src_dev=jnp.asarray(topo.src_dev.astype(np.int32)),
        sw_sw=jnp.asarray(sw_sw),
        fabric_link=jnp.asarray(fabric_ext.astype(np.float32)),
        fabric_path=jnp.asarray((fabric_ext[path] & hopmask)
                                .astype(np.float32)),
        cls_path=jnp.asarray(link_class[path]),
        n_hops=jnp.asarray(n_hops),
        base_rtt=jnp.asarray(base_rtt), delay_steps=jnp.asarray(delay_steps),
        line=jnp.asarray(line), bdp=jnp.asarray(bdp),
        fanin=jnp.asarray(fanin),
        size=jnp.asarray(size),
        group=jnp.asarray(group), dep=jnp.asarray(dep),
        sdelay=jnp.asarray(sdelay),
        gsize=jnp.asarray(gsize),
        active=jnp.asarray(active),
        dev_buf=jnp.asarray(topo.dev_buf.astype(np.float32)),
        r_hop=tuple(hop_arrs), r_qlink=ql_a, r_qport=qp_a,
        r_group=gr_a, r_pause=pa_a, r_qdev=qd_a,
    )
    return pp, plan


def _flow_ctx(pp: dict, F: int) -> FlowCtx:
    """The typed per-flow context every policy's ``init`` receives — the
    whole engine->init contract, no signature introspection."""
    return FlowCtx(line=pp["line"], bdp=pp["bdp"], fanin=pp["fanin"],
                   n_flows=F)


def _wire_of(policy: Policy, cc_params: dict | None):
    """Wire factor: static per policy, traced via the ``_wire`` param for
    stacked policies (members differ — HPCC INT carries +4.8%)."""
    if cc_params is not None and "_wire" in cc_params:
        return jnp.asarray(cc_params["_wire"], jnp.float32)
    return jnp.float32(policy.wire_factor)


def _n_qrows(cfg: EngineConfig) -> int:
    total = cfg.max_steps * (cfg.max_extends + 1)
    return -(-total // cfg.queue_stride) if cfg.queue_stride > 0 else 0


def _init_carry(pp, plan: _Plan, policy: Policy, cfg: EngineConfig,
                cc_params: dict | None = None, faulty: bool = False):
    Fp, Lk, D = plan.n_flows_pad, plan.n_links, plan.n_dev
    carry = dict(
        backlog=jnp.zeros((Fp, MAXHOP), jnp.float32),
        remaining=pp["size"] * _wire_of(policy, cc_params),
        injected=jnp.zeros(Fp, jnp.float32),
        delivered=jnp.zeros(Fp, jnp.float32),
        done=~pp["active"],           # padded flows are born finished
        t_finish=jnp.full(Fp, jnp.inf, jnp.float32),
        g_count=jnp.zeros(plan.n_groups_pad, jnp.float32),
        # empty groups (possible after topology mapping) complete at t=0
        g_time=jnp.where(pp["gsize"] < 0.5, 0.0, jnp.inf).astype(jnp.float32),
        paused=jnp.zeros(Lk + 1, bool),
        pause_count=jnp.zeros(D, jnp.float32),
        hist_q=jnp.zeros((plan.ring, Lk + 1), jnp.float32),
        hist_tx=jnp.zeros((plan.ring, Lk + 1), jnp.float32),
        # copy: some policies' init returns state aliasing pp arrays (e.g.
        # DCTCP keeps bdp); the carry is donated, so aliases would delete
        # buffers that pp still needs on the next run
        cc=jax.tree_util.tree_map(lambda x: jnp.asarray(x).copy(),
                                  policy.init(_flow_ctx(pp, Fp))),
        soft=jnp.zeros((), jnp.float32),
        # run health (observers; the step no-op gate also keys on diverged)
        diverged=jnp.zeros((), bool),
        deadlock_step=jnp.full((), -1, jnp.int32),
        storm_run=jnp.zeros((), jnp.int32),
        storm_step=jnp.full((), -1, jnp.int32),
    )
    if faulty:
        carry["lost"] = jnp.zeros(Fp, jnp.float32)      # dropped in-network
        carry["dup"] = jnp.zeros(Fp, jnp.float32)       # GBN resend overhead
        carry["loss_sig"] = jnp.zeros(Fp, jnp.float32)  # EWMA loss fraction
    if cfg.queue_stride > 0:
        carry["qbuf"] = jnp.zeros((_n_qrows(cfg), D), jnp.float32)
    return carry


def _make_step(policy: Policy, cfg: EngineConfig, plan: _Plan,
               faulty: bool = False):
    dt = cfg.dt
    Lk = plan.n_links
    stride = cfg.queue_stride
    n_qrows = _n_qrows(cfg)
    D = plan.n_dev
    # pause-cycle reachability via repeated squaring: after k rounds S
    # covers paths of length up to 2^k, so ceil(log2(D)) rounds suffice
    dl_rounds = max(1, (max(D, 2) - 1).bit_length())

    # backend dispatch: route stages 1-2 (+ the gather reductions and the
    # PFC pause signal) through the fused Pallas engine-step kernels when
    # the resolved impl is "pallas" and the policy's update is expressible
    # in the kernel's flat-array form; stacked product policies (tuple
    # state + lax.switch) stay on the jnp path.  The jnp branch below is
    # the historical step, emitted unchanged — goldens stay bitwise.
    use_kernel = (resolve_step_impl(cfg) == "pallas"
                  and kernel_eligible(policy))
    if use_kernel:
        from repro.kernels import default_interpret
        from repro.kernels.engine_step import ops as es_ops
        interpret = default_interpret(None)

        def reduce_(strategy, arrs, vals):
            if strategy[0] == "gather":
                return es_ops.segment_reduce(vals, arrs["idx"], strategy[1],
                                             strategy[2],
                                             interpret=interpret)
            return _reduce(strategy, arrs, vals)
    else:
        reduce_ = _reduce

    def step(carry, it, pp, cc_params, fab, flt):
        def _pause_cycle(paused):
            """Any cycle in the switch->switch PFC wait-for graph?  Link l
            paused means src_dev(l) waits on dst_dev(l) to resume."""
            e = (paused[:Lk] & pp["sw_sw"]).astype(jnp.float32)
            adj = jnp.zeros((D, D), jnp.float32)
            adj = adj.at[pp["src_dev"], pp["dst_dev"][:Lk]].add(e)
            S = jnp.minimum(adj, 1.0)
            for _ in range(dl_rounds):
                S = jnp.minimum(S + S @ S, 1.0)
            return jnp.any(jnp.diagonal(S) > 0.5)

        wire = _wire_of(policy, cc_params)
        path, hopmask = pp["path"], pp["hopmask"]
        t = it.astype(jnp.float32) * dt
        # per-link-class fabric knobs (scalar leaves broadcast to uniform)
        kmin_h = _per_class(fab.kmin)[pp["cls_path"]]     # (F, MAXHOP)
        kmax_h = _per_class(fab.kmax)[pp["cls_path"]]
        pmax_h = _per_class(fab.pmax)[pp["cls_path"]]
        # ---- 1. delayed signals ------------------------------------------
        idx = jnp.maximum(it - pp["delay_steps"], 0) % plan.ring
        flat = idx[:, None] * (Lk + 1) + path            # (F, MAXHOP)
        q_d = carry["hist_q"].reshape(-1)[flat]
        tx_d = carry["hist_tx"].reshape(-1)[flat]
        caps = pp["caps_path"]
        if use_kernel:
            # ---- 1+2 fused: signals + CC update in one Pallas pass ------
            # ECN misconfiguration folds into the marking ceiling (same
            # product as the jnp path's post-clip scale)
            pmax_eff = pmax_h
            if faulty:
                pmax_eff = pmax_eff * _per_class(flt.ecn_scale)[pp["cls_path"]]
            loss = (carry["loss_sig"] if faulty
                    else jnp.zeros_like(pp["line"]))
            cc, rate, win = es_ops.fused_step(
                policy, q_d=q_d, tx_d=tx_d, caps=caps,
                ecn_mask=pp["ecn_mask"], hopmask=hopmask,
                kmin_h=kmin_h, kmax_h=kmax_h, pmax_h=pmax_eff,
                base_rtt=pp["base_rtt"], line=pp["line"], loss=loss,
                state=carry["cc"], params=cc_params, t=t, dt=dt,
                t_base_util=cfg.t_base_util, interpret=interpret)
        else:
            rtt = pp["base_rtt"] + (q_d / caps * hopmask).sum(1)
            mark = jnp.clip((q_d - kmin_h) / jnp.maximum(kmax_h - kmin_h, 1.0),
                            0.0, 1.0) * pmax_h
            if faulty:
                # ECN misconfiguration: scale marking probability (0 = broken)
                mark = mark * _per_class(flt.ecn_scale)[pp["cls_path"]]
            mark = mark * pp["ecn_mask"]
            ecn = 1.0 - jnp.prod(1.0 - mark, axis=1)
            util_l = tx_d / caps + q_d / (caps * cfg.t_base_util)
            util = jnp.max(jnp.where(hopmask, util_l, 0.0), axis=1)
            if faulty:
                sig = Signals(ecn=ecn, rtt=rtt, util=util, t=t,
                              dt=jnp.float32(dt), line=pp["line"],
                              base_rtt=pp["base_rtt"], loss=carry["loss_sig"])
            else:
                sig = Signals(ecn=ecn, rtt=rtt, util=util, t=t,
                              dt=jnp.float32(dt), line=pp["line"],
                              base_rtt=pp["base_rtt"])

            # ---- 2. CC update ---------------------------------------------
            cc, rate, win = policy.update(cc_params, carry["cc"], sig)

        # ---- 3. injection --------------------------------------------------
        dep = pp["dep"]
        g_done = carry["g_count"] >= pp["gsize"] - 0.5
        dep_ok = jnp.where(dep >= 0, g_done[jnp.maximum(dep, 0)], True)
        dep_t = jnp.where(dep >= 0, carry["g_time"][jnp.maximum(dep, 0)], 0.0)
        started = dep_ok & (t >= dep_t + pp["sdelay"])
        inflight = carry["injected"] - carry["delivered"]
        if faulty:
            # lost bytes are not in flight (the NIC saw the NACK/timeout)
            inflight = inflight - carry["lost"]
        room = jnp.maximum(win - inflight, 0.0)
        inj = jnp.minimum(jnp.minimum(rate * dt, room), carry["remaining"])
        inj = jnp.where(started & (pp["n_hops"] > 0), jnp.maximum(inj, 0.0), 0.0)
        backlog = carry["backlog"].at[:, 0].add(inj)
        remaining = carry["remaining"] - inj
        injected = carry["injected"] + inj

        # ---- 4. PFC gates (per-port) ---------------------------------------
        gate = ~carry["paused"]
        rem_cap = pp["cap"] * dt * gate
        if faulty:
            # time-scheduled capacity faults on fabric links: degradation
            # windows and periodic link flaps (down for flap_down out of
            # every flap_period seconds)
            deg = _per_class(flt.degrade)[pp["link_class"]]
            in_deg = (t >= flt.degrade_t0) & (t < flt.degrade_t1)
            capmul = jnp.where(in_deg & (pp["fabric_link"] > 0), deg, 1.0)
            period = jnp.asarray(flt.flap_period, jnp.float32)
            phase = jnp.mod(t - flt.flap_t0, jnp.maximum(period, 1e-9))
            flap_down = ((period > 0) & (t >= flt.flap_t0)
                         & (phase < flt.flap_down))
            capmul = jnp.where(flap_down & (pp["fabric_link"] > 0),
                               0.0, capmul)
            rem_cap = rem_cap * capmul
        rem_cap = rem_cap.at[Lk].set(1e18)

        # ---- 5. hop-ordered forwarding -------------------------------------
        delivered = carry["delivered"]
        tx_bytes = jnp.zeros(Lk + 1, jnp.float32)
        if faulty:
            # per-hop drop probability: fabric links only (NVLink lossless)
            loss_p = (_per_class(flt.loss_rate)[pp["cls_path"]]
                      * pp["fabric_path"])
            lost_step = jnp.zeros_like(carry["lost"])
        for h in range(MAXHOP):
            if plan.hop[h][0] == "empty":   # no flow ever uses this hop slot
                continue
            dem = reduce_(plan.hop[h], pp["r_hop"][h], backlog[:, h])
            frac = jnp.where(dem > 0,
                             jnp.minimum(1.0, rem_cap / jnp.maximum(dem, 1e-9)),
                             0.0)
            moved = backlog[:, h] * frac[path[:, h]]
            backlog = backlog.at[:, h].add(-moved)
            if faulty:
                # bytes dropped on this hop consumed upstream capacity but
                # leave the network; they re-enter `remaining` below
                drop = moved * loss_p[:, h]
                lost_step = lost_step + drop
                moved = moved - drop
            last = pp["n_hops"] == (h + 1)
            delivered = delivered + jnp.where(last, moved, 0.0)
            if h + 1 < MAXHOP:
                backlog = backlog.at[:, h + 1].add(jnp.where(last, 0.0, moved))
            movedsum = frac * dem          # == per-link sum of `moved`
            rem_cap = jnp.maximum(rem_cap - movedsum, 0.0)
            tx_bytes = tx_bytes + movedsum

        if faulty:
            # ---- 5b. loss recovery (IRN vs go-back-N) ----------------------
            lost = carry["lost"] + lost_step
            live = jnp.maximum(injected - delivered - lost, 0.0)
            gbn = jnp.asarray(flt.gbn, jnp.float32)
            mtu = jnp.maximum(jnp.asarray(flt.mtu, jnp.float32), 1.0)
            # IRN (selective retransmit): only the lost bytes are resent.
            # go-back-N: each lost packet (lost_step/mtu of them) resends on
            # average half the NIC's outstanding window too.  The window is
            # the in-network bytes capped at the path BDP: fluid "live"
            # includes queued backlog, which a real NIC's send window never
            # covers — uncapped, incast GBN resends faster than the
            # bottleneck drains and can never terminate
            w_out = jnp.minimum(live, pp["line"] * pp["base_rtt"])
            dup_step = gbn * jnp.minimum(lost_step * w_out / (2.0 * mtu),
                                         live)
            remaining = remaining + lost_step + dup_step
            dup = carry["dup"] + dup_step
            # per-flow EWMA loss fraction (the `loss` CC signal, read next
            # step so it is RTT-delayed like the other signals)
            a = jnp.minimum(dt / pp["base_rtt"], 1.0)
            traf = lost_step + (delivered - carry["delivered"])
            frac_l = lost_step / jnp.maximum(traf, 1.0)
            loss_sig = jnp.where(traf > 0,
                                 (1.0 - a) * carry["loss_sig"] + a * frac_l,
                                 carry["loss_sig"])

        # ---- 6. queues ------------------------------------------------------
        q_link = reduce_(plan.qlink, pp["r_qlink"], backlog.reshape(-1))
        xoff_l = _per_class(fab.xoff)[pp["link_class"]]   # (Lk+1,)
        xon_l = _per_class(fab.xon)[pp["link_class"]]
        can = pp["can_pause"]
        if faulty:
            # PFC misconfiguration / lossy-RoCE: pfc_on=0 disables pausing
            can = can & (_per_class(flt.pfc_on)[pp["link_class"]] > 0.5)
        if use_kernel and plan.qport[0] == "gather":
            # ---- 6b+7 fused: per-port occupancy reduction + hysteresis --
            q_port, paused = es_ops.segment_reduce_pfc(
                backlog.reshape(-1), pp["r_qport"]["idx"], plan.qport[1],
                plan.qport[2], xoff_l, xon_l, can, carry["paused"],
                interpret=interpret)
        else:
            # per-ingress-port occupancy at the receiving switch
            q_port = reduce_(plan.qport, pp["r_qport"], backlog.reshape(-1))

            # ---- 7. PFC per-port hysteresis ---------------------------------
            over = (q_port > xoff_l) & can
            under = q_port < xon_l
            paused = jnp.where(over, True,
                               jnp.where(under, False, carry["paused"]))
        # PAUSE frames: one on the off-transition + periodic refreshes while
        # the port stays paused (how NS3 counts them)
        frames = ((paused & ~carry["paused"])[:Lk].astype(jnp.float32)
                  + paused[:Lk].astype(jnp.float32) * (dt / cfg.pause_resend))
        pause_count = carry["pause_count"] + reduce_(plan.pause, pp["r_pause"],
                                                     frames)

        # ---- 8. completion --------------------------------------------------
        wire_size = pp["size"] * wire
        if faulty:
            # duplicates arrive at the receiver and are discarded there:
            # goodput = delivered - dup, so completion needs dup extra bytes
            data_done = delivered >= wire_size + dup - cfg.eps_done
        else:
            data_done = delivered >= wire_size - cfg.eps_done
        marker_done = (pp["n_hops"] == 0) & started
        newly = ~carry["done"] & (jnp.where(pp["n_hops"] > 0, data_done, marker_done))
        done = carry["done"] | newly
        # completion happens at the END of this step's transfer window
        t_finish = jnp.where(newly, t + dt, carry["t_finish"])
        g_count = carry["g_count"] + reduce_(plan.group, pp["r_group"],
                                             newly.astype(jnp.float32))
        g_done_new = (g_count >= pp["gsize"] - 0.5) & ~(carry["g_count"] >= pp["gsize"] - 0.5)
        g_time = jnp.where(g_done_new, t + dt, carry["g_time"])

        # ---- 9. history + soft cost ----------------------------------------
        hist_q = lax.dynamic_update_slice_in_dim(
            carry["hist_q"], q_link[None], it % plan.ring, axis=0)
        hist_tx = lax.dynamic_update_slice_in_dim(
            carry["hist_tx"], (tx_bytes / dt)[None], it % plan.ring, axis=0)
        if faulty:
            goodput = jnp.clip(delivered - dup, 0.0, wire_size)
        else:
            goodput = jnp.minimum(delivered, wire_size)
        undeliv = jnp.sum(wire_size - goodput)
        soft = carry["soft"] + dt * undeliv / jnp.maximum(jnp.sum(wire_size), 1.0)

        # ---- 10. run health (observers; never touch the physics above) ------
        # pause storm: >= storm_frac of pausable ports paused for
        # storm_steps consecutive steps
        n_pausable = jnp.maximum(
            jnp.sum(pp["can_pause"][:Lk].astype(jnp.float32)), 1.0)
        pfrac = jnp.sum(paused[:Lk].astype(jnp.float32)) / n_pausable
        storm_run = jnp.where(pfrac >= cfg.storm_frac,
                              carry["storm_run"] + 1, 0)
        storm_step = jnp.where((carry["storm_step"] < 0)
                               & (storm_run >= cfg.storm_steps),
                               it, carry["storm_step"])
        # pause-cycle deadlock: checked every deadlock_check_every steps
        # while switch->switch pauses exist and no cycle was seen yet
        dl_candidates = jnp.any(paused[:Lk] & pp["sw_sw"])
        do_check = ((it % cfg.deadlock_check_every == 0) & dl_candidates
                    & (carry["deadlock_step"] < 0))
        cycle = lax.cond(do_check, _pause_cycle,
                         lambda _: jnp.zeros((), bool), paused)
        deadlock_step = jnp.where(cycle & (carry["deadlock_step"] < 0),
                                  it, carry["deadlock_step"])
        # non-finite guard: freeze the lane at the first bad state instead
        # of poisoning a whole vmapped batch (the step no-op gate and the
        # early-exit loop both key on `diverged`)
        probe = (jnp.sum(backlog) + jnp.sum(remaining) + jnp.sum(rate)
                 + jnp.sum(q_link) + soft)
        diverged = carry["diverged"] | ~jnp.isfinite(probe)

        new_carry = dict(
            backlog=backlog, remaining=remaining, injected=injected,
            delivered=delivered, done=done, t_finish=t_finish,
            g_count=g_count, g_time=g_time, paused=paused,
            pause_count=pause_count, hist_q=hist_q, hist_tx=hist_tx,
            cc=cc, soft=soft,
            diverged=diverged, deadlock_step=deadlock_step,
            storm_run=storm_run, storm_step=storm_step)
        if faulty:
            new_carry["lost"] = lost
            new_carry["dup"] = dup
            new_carry["loss_sig"] = loss_sig
        if stride > 0:
            # strided timeline recording; rows for skipped steps are dropped
            q_dev = reduce_(plan.qdev, pp["r_qdev"], q_link[:Lk])
            row = jnp.where(it % stride == 0, it // stride, n_qrows)
            new_carry["qbuf"] = carry["qbuf"].at[row].set(q_dev, mode="drop")
        return new_carry

    return step


def _make_run(policy: Policy, cfg: EngineConfig, plan: _Plan,
              early_exit: bool, faulty: bool = False, remat: bool = False):
    """Build the full (jittable) stepping loop.

    Each step is gated on ``done.all() | diverged | (it >= total)`` so
    finished (or frozen non-finite) lanes are no-ops; with ``early_exit``
    the chunked while_loop additionally stops integrating at the first
    chunk boundary where every flow is done (or the lane diverged).  Both
    variants therefore produce bitwise-identical carries.

    ``remat`` (fixed-length path only) rematerializes the scan in
    ``cfg.chunk_steps``-sized segments: each segment is wrapped in
    ``jax.checkpoint``, so reverse-mode AD stores one carry per segment
    plus one segment's activations instead of every step's — O(sqrt)
    memory for long-horizon gradients (the ``repro.learn`` trainer's
    path).  The forward computation is the same gated step sequence, so
    forward values match the monolithic scan exactly.
    """
    if remat and early_exit:
        raise ValueError("remat applies to the fixed-length scan only "
                         "(early_exit=False): lax.while_loop is not "
                         "reverse-mode differentiable anyway")
    step = _make_step(policy, cfg, plan, faulty)
    total = cfg.max_steps * (cfg.max_extends + 1)
    chunk = max(1, min(cfg.chunk_steps, total))

    def run(carry, pp, cc_params, fab, flt):
        def body(c, it):
            c2 = lax.cond(jnp.all(c["done"]) | c["diverged"] | (it >= total),
                          lambda c: c,
                          lambda c: step(c, it, pp, cc_params, fab, flt),
                          c)
            return c2, None

        if not early_exit:
            if remat:
                # ceil(total/chunk) checkpointed segments; trailing
                # it >= total steps are gated no-ops, so the padded tail
                # is inert and forward values match the monolithic scan
                n_seg = -(-total // chunk)

                @jax.checkpoint
                def seg(c, it0):
                    c, _ = lax.scan(
                        body, c, it0 + jnp.arange(chunk, dtype=jnp.int32))
                    return c, None

                carry2, _ = lax.scan(
                    seg, carry,
                    jnp.arange(n_seg, dtype=jnp.int32) * chunk)
                return carry2, jnp.int32(total)
            carry2, _ = lax.scan(body, carry, jnp.arange(total, dtype=jnp.int32))
            return carry2, jnp.int32(total)

        def w_body(state):
            c, it0 = state
            c, _ = lax.scan(body, c, it0 + jnp.arange(chunk, dtype=jnp.int32))
            return c, it0 + chunk

        def w_cond(state):
            c, it0 = state
            return (~(jnp.all(c["done"]) | c["diverged"])) & (it0 < total)

        carry2, it_end = lax.while_loop(w_cond, w_body, (carry, jnp.int32(0)))
        return carry2, jnp.minimum(it_end, total)

    return run


# ---------------------------------------------------------------------------
# compile cache: (policy identity, cfg, plan) -> jitted run
# ---------------------------------------------------------------------------

_RUN_CACHE: dict = {}


def _policy_cache_key(policy: Policy):
    """Hashable identity of a policy's *logic* (params ride along traced,
    but ``init`` may bake closure defaults into the carry, so include the
    default params in the key)."""
    return (policy.name, float(policy.wire_factor),
            getattr(policy.init, "__code__", policy.init),
            getattr(policy.update, "__code__", policy.update),
            tuple(sorted((k, float(v)) for k, v in policy.params.items())),
            # stacked policies share closure code objects; their member
            # identity tokens live in key_extra
            policy.key_extra)


def compiled_run(policy: Policy, cfg: EngineConfig, plan: _Plan,
                 early_exit: bool = True, faulty: bool = False):
    """Jitted stepping loop, cached across scenarios with equal plans.

    The carry (arg 0) is donated: every run must pass a freshly built one.
    Fabric scalars on ``cfg`` are normalized out of the key (they arrive
    traced via FabricParams), so a fabric sweep never recompiles.
    ``faulty`` keys the fault-injection compile path: the default (inert)
    FaultSpec runs the historical fault-free step, so lossless results are
    bitwise-identical with the fault layer present.
    """
    key = (_policy_cache_key(policy), _cfg_static(cfg), plan, early_exit,
           faulty)
    if key not in _RUN_CACHE:
        run = _make_run(policy, cfg, plan, early_exit, faulty)
        _RUN_CACHE[key] = jax.jit(run, donate_argnums=(0,))
    return _RUN_CACHE[key]


class Simulator:
    """Compiled fluid simulation of one (topology, schedule, policy).

    ``pad_flows`` / ``pad_groups`` (see ``_prep``) let ``SweepRunner``
    bucket same-shaped scenarios onto one compiled executable.
    """

    def __init__(self, topo: Topology, sched: Schedule, policy: Policy,
                 cfg: EngineConfig = EngineConfig(),
                 pad_flows: int | None = None, pad_groups: int | None = None,
                 fabric_params: FabricParams | None = None,
                 fault_spec: FaultSpec | None = None):
        self.topo, self.sched, self.policy, self.cfg = topo, sched, policy, cfg
        self.fabric = _as_fabric(fabric_params, cfg)
        self.fault = _as_fault(fault_spec)
        self.pp, self.plan = _prep(topo, sched, cfg, pad_flows, pad_groups)
        self._soft_jit = None

    def run(self, cc_params: dict | None = None, early_exit: bool = True,
            fabric_params: FabricParams | None = None,
            fault_spec: FaultSpec | None = None) -> Results:
        params = cc_params if cc_params is not None else self.policy.params
        fab = fabric_params if fabric_params is not None else self.fabric
        flt = fault_spec if fault_spec is not None else self.fault
        faulty = is_faulty(flt)
        fn = compiled_run(self.policy, self.cfg, self.plan, early_exit,
                          faulty)
        carry = _init_carry(self.pp, self.plan, self.policy, self.cfg,
                            params, faulty)
        carry, steps = fn(carry, self.pp, params, fab, flt)
        return self._results(carry, int(steps))

    def _results(self, carry, steps_run: int) -> Results:
        F, G = self.plan.n_flows, self.plan.n_groups
        t_fin = np.asarray(carry["t_finish"])[:F]
        done = np.asarray(carry["done"])[:F]
        if self.cfg.queue_stride > 0:
            dev_queue = np.asarray(carry["qbuf"])
            rows = -(-steps_run // self.cfg.queue_stride)
            dev_queue = dev_queue[:rows]
        else:
            dev_queue = np.zeros((0, self.plan.n_dev), np.float32)
        finished = bool(done.all())
        diverged = bool(carry["diverged"])
        deadlock_step = int(carry["deadlock_step"])
        extend_exhausted = not finished and not diverged
        if extend_exhausted:
            total = self.cfg.max_steps * (self.cfg.max_extends + 1)
            warnings.warn(
                f"step budget exhausted: {int((~done).sum())}/{F} flows "
                f"unfinished after {total} steps (max_steps="
                f"{self.cfg.max_steps}, max_extends={self.cfg.max_extends}) "
                f"for policy {self.policy.name!r} on {self.topo.name!r}; "
                "completion_time is a lower bound — raise max_steps/"
                "max_extends or treat this cell as invalid",
                RuntimeWarning, stacklevel=3)
        return Results(
            finished=finished,
            completion_time=float(np.max(np.where(np.isfinite(t_fin), t_fin, 0.0))),
            t_finish=t_fin,
            group_time=np.asarray(carry["g_time"])[:G],
            group_names=self.sched.group_names,
            pause_count=np.asarray(carry["pause_count"]),
            dev_queue=dev_queue,
            dt=self.cfg.dt,
            delivered=np.asarray(carry["delivered"])[:F],
            soft_cost=float(carry["soft"]),
            meta={"policy": self.policy.name, "topo": self.topo.name,
                  "n_flows": self.sched.n_flows, "steps_run": steps_run,
                  "queue_stride": self.cfg.queue_stride},
            deadlocked=deadlock_step >= 0,
            deadlock_step=deadlock_step,
            storm_step=int(carry["storm_step"]),
            diverged=diverged,
            extend_exhausted=extend_exhausted,
            lost=(np.asarray(carry["lost"])[:F] if "lost" in carry
                  else None),
        )

    # -- differentiable objective -------------------------------------------
    def soft_cost_fn(self, remat: bool = False):
        """Pure ``(cc_params, fabric_params=default) -> soft_cost`` suitable
        for grad/vmap/jit — differentiable through the fabric knobs too.

        Uses the monolithic (fixed-length) scan: ``lax.while_loop`` is not
        reverse-mode differentiable.  The integrand freezes once every flow
        completes (steps become no-ops), so the integral is insensitive to
        the step budget's tail.

        ``remat=True`` selects the rematerialized scan (``jax.checkpoint``
        over ``cfg.chunk_steps``-sized segments): same forward value,
        O(total/chunk + chunk) instead of O(total) carries live during the
        backward pass — the memory-feasible path for long-horizon training
        (``repro.learn``).
        """
        faulty = is_faulty(self.fault)
        run = _make_run(self.policy, self.cfg, self.plan, early_exit=False,
                        faulty=faulty, remat=remat)
        pp, plan, policy, cfg = self.pp, self.plan, self.policy, self.cfg
        default_fab, default_flt = self.fabric, self.fault

        def cost(cc_params, fabric_params=default_fab):
            carry = _init_carry(pp, plan, policy, cfg, cc_params, faulty)
            carry, _ = run(carry, pp, cc_params, fabric_params, default_flt)
            return carry["soft"]

        return cost

    def soft_cost(self, cc_params,
                  fabric_params: FabricParams | None = None) -> jnp.ndarray:
        """Differentiable objective: integral of undelivered fraction.

        Jitted and cached per Simulator; compose ``soft_cost_fn`` yourself
        for grad/vmap pipelines (as ``core/autotune.py`` does)."""
        if self._soft_jit is None:
            self._soft_jit = jax.jit(self.soft_cost_fn())
        return self._soft_jit(cc_params,
                              fabric_params if fabric_params is not None
                              else self.fabric)


def simulate(topo, sched, policy, cfg: EngineConfig = EngineConfig(),
             fabric_params: FabricParams | None = None,
             fault_spec: FaultSpec | None = None) -> Results:
    return Simulator(topo, sched, policy, cfg, fabric_params=fabric_params,
                     fault_spec=fault_spec).run()
