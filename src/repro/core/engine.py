"""Network layer: fixed-timestep, fully-vectorized fluid-flow simulator.

JAX/TPU-native adaptation of the paper's NS3 packet-level layer (DESIGN.md
§2): per-flow/per-link flat arrays stepped inside one ``lax.scan``.

Per step Δt:
  1. delayed signals (ECN fraction, RTT, HPCC INT utilisation) read from a
     per-link history ring at t - base_rtt(flow)
  2. CC policy update -> per-flow rate / window
  3. paced, window-gated injection into the source NIC egress queue
  4. hop-ordered fluid forwarding with per-link capacity accounting and
     proportional backlog drain (per-flow per-hop backlog => exact byte
     conservation)
  5. PFC: per-switch buffer hysteresis (X_OFF/X_ON) pauses all upstream
     links into that switch; pause transitions are counted (Fig 9 metric)
  6. dependency groups: flows start when their dep group completes (+ a
     compute delay), giving chunk pipelining and workload DAGs

The engine is differentiable w.r.t. CC policy parameters: `soft_cost`
integrates the undelivered fraction over time (see core/autotune.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.cc import Policy
from repro.core.collectives import Schedule
from repro.core.topology import MAXHOP, Topology


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    dt: float = 1e-6
    max_steps: int = 20_000
    max_extends: int = 4          # re-run segments until all flows finish
    hist: int = 512               # feedback delay ring (steps)
    # ECN / RED marking at switch egress queues
    kmin: float = 400e3
    kmax: float = 1600e3
    pmax: float = 0.2
    # PFC per-ingress-port hysteresis (bytes queued in the switch that
    # arrived through that port; pause is sent to that port's sender only)
    xoff: float = 1e6
    xon: float = 0.8e6
    t_base_util: float = 10e-6    # HPCC qlen->util horizon
    eps_done: float = 512.0       # completion slack (bytes)
    pause_resend: float = 5e-6    # PAUSE frame refresh while a port is paused


@dataclasses.dataclass
class Results:
    finished: bool
    completion_time: float        # max flow finish (s)
    t_finish: np.ndarray          # (F,)
    group_time: np.ndarray        # (G,)
    group_names: list
    pause_count: np.ndarray       # (D,) PFC pause transitions per device
    dev_queue: np.ndarray         # (T, D) per-device queue bytes timeline
    dt: float
    delivered: np.ndarray
    soft_cost: float
    meta: dict


def _prep(topo: Topology, sched: Schedule, cfg: EngineConfig):
    Lk = topo.n_links
    path = np.where(sched.path < 0, Lk, sched.path).astype(np.int32)
    cap = np.concatenate([topo.cap, [1e18]]).astype(np.float32)
    lat = np.concatenate([topo.lat, [0.0]]).astype(np.float32)
    ecn_on = np.concatenate([topo.ecn_on, [False]])
    dst_dev = np.concatenate([topo.dst_dev, [topo.n_devices]]).astype(np.int32)

    # ingress map: backlog at hop h arrived via link path[:, h-1] (h >= 1);
    # hop-0 backlog is the host's own send queue (never paused by PFC)
    ingress = np.full_like(path, Lk)
    ingress[:, 1:] = np.where(sched.path[:, 1:] >= 0, path[:, :-1], Lk)
    # a port can be paused only if its receiver is a PFC-capable switch
    dev_sw_ext = np.concatenate([topo.dev_is_switch, [False]])
    fabric_ext = np.concatenate([topo.fabric, [False]])
    can_pause = dev_sw_ext[dst_dev] & fabric_ext

    # static fan-in: CONCURRENT flows sharing each flow's most-contended
    # link.  Deterministic schedules serialize phases via dep groups, so
    # only same-group flows contend — exactly the knowledge the paper says
    # an optimized CC should exploit (§IV-E).
    link_load = np.zeros(Lk + 1, np.float64)
    for g in range(max(sched.n_groups, 1)):
        in_g = (sched.group == g) & (sched.size > 0)
        if not in_g.any():
            continue
        load_g = np.zeros(Lk + 1, np.float64)
        for h in range(path.shape[1]):
            np.add.at(load_g, path[in_g, h], 1.0)
        link_load = np.maximum(link_load, load_g)
    link_load[Lk] = 1.0
    fanin = np.ones(sched.n_flows, np.float64)
    for h in range(path.shape[1]):
        valid = sched.path[:, h] >= 0
        fanin = np.maximum(fanin, np.where(valid, link_load[path[:, h]], 1.0))

    hopmask = (sched.path >= 0)
    base_rtt = 2.0 * (lat[path] * hopmask).sum(1)
    # serialization/propagation floor so zero-latency markers behave
    base_rtt = np.maximum(base_rtt, 1e-7).astype(np.float32)
    delay_steps = np.clip(np.round(base_rtt / cfg.dt), 1, cfg.hist - 1).astype(np.int32)
    first = path[:, 0]
    line = cap[first].astype(np.float32)
    bdp = (line * base_rtt).astype(np.float32)
    gsize = np.zeros(sched.n_groups, np.float32)
    np.add.at(gsize, sched.group, 1.0)
    return dict(
        path=jnp.asarray(path), cap=jnp.asarray(cap),
        ecn_on=jnp.asarray(ecn_on), dst_dev=jnp.asarray(dst_dev),
        ingress=jnp.asarray(ingress), can_pause=jnp.asarray(can_pause),
        hopmask=jnp.asarray(hopmask),
        n_hops=jnp.asarray(sched.n_hops),
        base_rtt=jnp.asarray(base_rtt), delay_steps=jnp.asarray(delay_steps),
        line=jnp.asarray(line), bdp=jnp.asarray(bdp),
        fanin=jnp.asarray(fanin.astype(np.float32)),
        size=jnp.asarray(sched.size.astype(np.float32)),
        group=jnp.asarray(sched.group), dep=jnp.asarray(sched.dep),
        sdelay=jnp.asarray(sched.delay.astype(np.float32)),
        gsize=jnp.asarray(gsize),
        src_dev=jnp.asarray(topo.src_dev),
        dev_is_switch=jnp.asarray(topo.dev_is_switch),
        dev_buf=jnp.asarray(topo.dev_buf.astype(np.float32)),
        n_links=Lk, n_dev=topo.n_devices, n_groups=sched.n_groups,
        n_flows=sched.n_flows,
    )


def _policy_init(policy: Policy, F: int, pp: dict):
    try:  # schedule-aware policies (StaticWindow) take the fan-in too
        return policy.init(F, pp["line"], pp["bdp"], fanin=pp["fanin"])
    except TypeError:
        return policy.init(F, pp["line"], pp["bdp"])


def _init_carry(pp, policy: Policy, cfg: EngineConfig):
    F, Lk, D, G = pp["n_flows"], pp["n_links"], pp["n_dev"], pp["n_groups"]
    return dict(
        backlog=jnp.zeros((F, MAXHOP), jnp.float32),
        remaining=pp["size"] * policy.wire_factor,
        injected=jnp.zeros(F, jnp.float32),
        delivered=jnp.zeros(F, jnp.float32),
        done=jnp.zeros(F, bool),
        t_finish=jnp.full(F, jnp.inf, jnp.float32),
        g_count=jnp.zeros(G, jnp.float32),
        # empty groups (possible after topology mapping) complete at t=0
        g_time=jnp.where(pp["gsize"] < 0.5, 0.0, jnp.inf).astype(jnp.float32),
        paused=jnp.zeros(Lk + 1, bool),
        pause_count=jnp.zeros(D, jnp.float32),
        hist_q=jnp.zeros((cfg.hist, Lk + 1), jnp.float32),
        hist_tx=jnp.zeros((cfg.hist, Lk + 1), jnp.float32),
        cc=_policy_init(policy, F, pp),
        soft=jnp.zeros((), jnp.float32),
    )


def _make_step(pp, policy: Policy, cfg: EngineConfig, cc_params):
    F, Lk, D, G = pp["n_flows"], pp["n_links"], pp["n_dev"], pp["n_groups"]
    dt = cfg.dt
    path, cap = pp["path"], pp["cap"]
    hopmask = pp["hopmask"]
    wire = jnp.float32(policy.wire_factor)

    def step(carry, it):
        t = it.astype(jnp.float32) * dt
        # ---- 1. delayed signals ------------------------------------------
        idx = jnp.maximum(it - pp["delay_steps"], 0) % cfg.hist
        q_d = carry["hist_q"][idx[:, None], path]        # (F, MAXHOP)
        tx_d = carry["hist_tx"][idx[:, None], path]
        caps = cap[path]
        rtt = pp["base_rtt"] + (q_d / caps * hopmask).sum(1)
        mark = jnp.clip((q_d - cfg.kmin) / (cfg.kmax - cfg.kmin), 0.0, 1.0) * cfg.pmax
        mark = mark * pp["ecn_on"][path] * hopmask
        ecn = 1.0 - jnp.prod(1.0 - mark, axis=1)
        util_l = tx_d / caps + q_d / (caps * cfg.t_base_util)
        util = jnp.max(jnp.where(hopmask, util_l, 0.0), axis=1)
        sig = {"ecn": ecn, "rtt": rtt, "util": util, "t": t, "dt": dt,
               "line": pp["line"], "base_rtt": pp["base_rtt"]}

        # ---- 2. CC update -------------------------------------------------
        cc, rate, win = policy.update(cc_params, carry["cc"], sig)

        # ---- 3. injection --------------------------------------------------
        dep = pp["dep"]
        g_done = carry["g_count"] >= pp["gsize"] - 0.5
        dep_ok = jnp.where(dep >= 0, g_done[jnp.maximum(dep, 0)], True)
        dep_t = jnp.where(dep >= 0, carry["g_time"][jnp.maximum(dep, 0)], 0.0)
        started = dep_ok & (t >= dep_t + pp["sdelay"])
        inflight = carry["injected"] - carry["delivered"]
        room = jnp.maximum(win - inflight, 0.0)
        inj = jnp.minimum(jnp.minimum(rate * dt, room), carry["remaining"])
        inj = jnp.where(started & (pp["n_hops"] > 0), jnp.maximum(inj, 0.0), 0.0)
        backlog = carry["backlog"].at[:, 0].add(inj)
        remaining = carry["remaining"] - inj
        injected = carry["injected"] + inj

        # ---- 4. PFC gates (per-port) ---------------------------------------
        gate = ~carry["paused"]
        rem_cap = cap * dt * gate
        rem_cap = rem_cap.at[Lk].set(1e18)

        # ---- 5. hop-ordered forwarding -------------------------------------
        delivered = carry["delivered"]
        tx_bytes = jnp.zeros(Lk + 1, jnp.float32)
        for h in range(MAXHOP):
            lid = path[:, h]
            dem = jnp.zeros(Lk + 1, jnp.float32).at[lid].add(backlog[:, h])
            frac = jnp.where(dem > 0, jnp.minimum(1.0, rem_cap / jnp.maximum(dem, 1e-9)), 0.0)
            moved = backlog[:, h] * frac[lid]
            backlog = backlog.at[:, h].add(-moved)
            last = pp["n_hops"] == (h + 1)
            delivered = delivered + jnp.where(last, moved, 0.0)
            if h + 1 < MAXHOP:
                backlog = backlog.at[:, h + 1].add(jnp.where(last, 0.0, moved))
            movedsum = jnp.zeros(Lk + 1, jnp.float32).at[lid].add(moved)
            rem_cap = jnp.maximum(rem_cap - movedsum, 0.0)
            tx_bytes = tx_bytes + movedsum

        # ---- 6. queues ------------------------------------------------------
        q_link = jnp.zeros(Lk + 1, jnp.float32).at[path.reshape(-1)].add(
            backlog.reshape(-1))
        q_dev = jnp.zeros(D, jnp.float32).at[pp["src_dev"]].add(q_link[:Lk])
        # per-ingress-port occupancy at the receiving switch
        q_port = jnp.zeros(Lk + 1, jnp.float32).at[pp["ingress"].reshape(-1)].add(
            backlog.reshape(-1))

        # ---- 7. PFC per-port hysteresis --------------------------------------
        over = (q_port > cfg.xoff) & pp["can_pause"]
        under = q_port < cfg.xon
        paused = jnp.where(over, True, jnp.where(under, False, carry["paused"]))
        # PAUSE frames: one on the off-transition + periodic refreshes while
        # the port stays paused (how NS3 counts them)
        frames = ((paused & ~carry["paused"])[:Lk].astype(jnp.float32)
                  + paused[:Lk].astype(jnp.float32) * (dt / cfg.pause_resend))
        pause_count = carry["pause_count"].at[pp["dst_dev"][:Lk]].add(frames)

        # ---- 8. completion --------------------------------------------------
        wire_size = pp["size"] * wire
        data_done = delivered >= wire_size - cfg.eps_done
        marker_done = (pp["n_hops"] == 0) & started
        newly = ~carry["done"] & (jnp.where(pp["n_hops"] > 0, data_done, marker_done))
        done = carry["done"] | newly
        # completion happens at the END of this step's transfer window
        t_finish = jnp.where(newly, t + dt, carry["t_finish"])
        g_count = carry["g_count"].at[pp["group"]].add(newly.astype(jnp.float32))
        g_done_new = (g_count >= pp["gsize"] - 0.5) & ~(carry["g_count"] >= pp["gsize"] - 0.5)
        g_time = jnp.where(g_done_new, t + dt, carry["g_time"])

        # ---- 9. history + soft cost ----------------------------------------
        hist_q = lax.dynamic_update_slice_in_dim(
            carry["hist_q"], q_link[None], it % cfg.hist, axis=0)
        hist_tx = lax.dynamic_update_slice_in_dim(
            carry["hist_tx"], (tx_bytes / dt)[None], it % cfg.hist, axis=0)
        undeliv = jnp.sum(wire_size - jnp.minimum(delivered, wire_size))
        soft = carry["soft"] + dt * undeliv / jnp.maximum(jnp.sum(wire_size), 1.0)

        new_carry = dict(
            backlog=backlog, remaining=remaining, injected=injected,
            delivered=delivered, done=done, t_finish=t_finish,
            g_count=g_count, g_time=g_time, paused=paused,
            pause_count=pause_count, hist_q=hist_q, hist_tx=hist_tx,
            cc=cc, soft=soft)
        return new_carry, q_dev

    return step


class Simulator:
    """Compiled fluid simulation of one (topology, schedule, policy)."""

    def __init__(self, topo: Topology, sched: Schedule, policy: Policy,
                 cfg: EngineConfig = EngineConfig()):
        self.topo, self.sched, self.policy, self.cfg = topo, sched, policy, cfg
        self.pp = _prep(topo, sched, cfg)

        def segment(carry, it0, cc_params):
            step = _make_step(self.pp, policy, cfg, cc_params)
            its = it0 + jnp.arange(cfg.max_steps)
            return lax.scan(step, carry, its)

        self._segment = jax.jit(segment)

    def run(self, cc_params: dict | None = None) -> Results:
        cfg = self.cfg
        params = cc_params if cc_params is not None else self.policy.params
        carry = _init_carry(self.pp, self.policy, cfg)
        qs = []
        for k in range(cfg.max_extends + 1):
            carry, q_dev = self._segment(carry, jnp.asarray(k * cfg.max_steps), params)
            qs.append(np.asarray(q_dev))
            if bool(np.asarray(carry["done"]).all()):
                break
        dev_queue = np.concatenate(qs, axis=0)
        t_fin = np.asarray(carry["t_finish"])
        finished = bool(np.asarray(carry["done"]).all())
        return Results(
            finished=finished,
            completion_time=float(np.max(np.where(np.isfinite(t_fin), t_fin, 0.0))),
            t_finish=t_fin,
            group_time=np.asarray(carry["g_time"]),
            group_names=self.sched.group_names,
            pause_count=np.asarray(carry["pause_count"]),
            dev_queue=dev_queue,
            dt=cfg.dt,
            delivered=np.asarray(carry["delivered"]),
            soft_cost=float(carry["soft"]),
            meta={"policy": self.policy.name, "topo": self.topo.name,
                  "n_flows": self.sched.n_flows},
        )

    def soft_cost(self, cc_params) -> jnp.ndarray:
        """Differentiable objective: integral of undelivered fraction."""
        carry = _init_carry(self.pp, self.policy, self.cfg)
        carry, _ = self._segment(carry, jnp.asarray(0), cc_params)
        return carry["soft"]


def simulate(topo, sched, policy, cfg: EngineConfig = EngineConfig()) -> Results:
    return Simulator(topo, sched, policy, cfg).run()
