"""End-to-end bridge: (architecture x mesh) collective schedule -> CLOS
fluid simulation under each CC policy.

Generalizes the paper's DLRM study to every assigned architecture: the
collective mix is extracted from the compiled dry-run HLO (hlo_comm), the
mesh axes are mapped onto the paper's CLOS fabric, and one training
iteration's communication is simulated under each CC policy.

The HLO replay is a scenario workload (``HLOReplaySpec``): drivers build
``ScenarioSpec(fabric, HLOReplaySpec(...), policy)`` per policy and hand
the list to a shared ``SweepRunner`` — no ad-hoc topology/schedule/policy
assembly.

Mesh->fabric mapping: mesh devices are laid out row-major (pod, data,
model); chips are packed 8 per node.  A "model"-axis collective therefore
spans consecutive chips (mostly intra-node NVLink + intra-rack NICs) while
"data"/"pod"-axis collectives stride across nodes and racks — the same
locality structure Mudigere et al. describe for production DLRM platforms.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import cc as cc_mod
from repro.core.collectives import Schedule, ScheduleBuilder, _direct_phase
from repro.core.engine import EngineConfig
from repro.core.hlo_comm import CollectiveOp
from repro.core.scenario import FabricSpec, ScenarioSpec
from repro.core.sweep import SweepRunner
from repro.core.topology import Topology


@dataclasses.dataclass
class PredictReport:
    policy: str
    comm_time: float
    pauses: float
    finished: bool
    # the step budget (max_steps x max_extends) ran out before the last
    # flow finished: comm_time is a LOWER BOUND, not a measurement —
    # consumers must mark or drop the cell (figures hatch it)
    extend_exhausted: bool = False


def mesh_groups(mesh_shape: tuple[int, ...], axis: int, n_gpus: int) -> list[list[int]]:
    """Device groups for a collective over ``axis`` of the mesh, mapped to
    GPU ids (device i -> gpu i % n_gpus when the mesh is larger than the
    modeled fabric slice)."""
    n = int(np.prod(mesh_shape))
    ids = np.arange(n).reshape(mesh_shape)
    moved = np.moveaxis(ids, axis, -1).reshape(-1, mesh_shape[axis])
    return [[int(g) % n_gpus for g in row] for row in moved]


def schedule_from_ops(topo: Topology, ops: list[CollectiveOp],
                      mesh_shape: tuple[int, ...],
                      axis_of_op: list[int], n_chunks: int = 4) -> Schedule:
    """Build a flow schedule replaying `ops` (op k over mesh axis
    axis_of_op[k]), chunked and chained like the workload layer does."""
    b = ScheduleBuilder(topo)
    prev = -1
    for k, op in enumerate(ops):
        groups = mesh_groups(mesh_shape, axis_of_op[k], topo.n_gpus)
        per_group_bytes = op.bytes_total * op.count / max(len(groups), 1)
        factor = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                  "all-to-all": 1.0, "collective-permute": 1.0}[op.kind]
        for c in range(n_chunks):
            g = b.new_group(f"op{k}_c{c}")
            for gi, members in enumerate(groups):
                m = sorted(set(members))
                if len(m) < 2:
                    continue
                P = len(m)
                pair_bytes = per_group_bytes * factor / n_chunks / P
                _direct_phase(b, m, pair_bytes, g, prev, 0.0,
                              salt=k * 65537 + c * 104729 + gi)
            prev = g
    return b.build()


@dataclasses.dataclass(frozen=True)
class HLOReplaySpec:
    """Scenario workload replaying a dry-run's collective mix."""
    ops: tuple                     # tuple[CollectiveOp, ...]
    mesh_shape: tuple
    axis_of_op: tuple
    n_chunks: int = 4

    def build_schedule(self, topo: Topology) -> Schedule:
        return schedule_from_ops(topo, list(self.ops), self.mesh_shape,
                                 list(self.axis_of_op), self.n_chunks)


def predict_policies(ops, mesh_shape, axis_of_op, policies=None,
                     topo: Topology | None = None,
                     cfg: EngineConfig | None = None,
                     runner: SweepRunner | None = None,
                     fabric: FabricSpec | None = None,
                     batched: bool | None = None) -> list[PredictReport]:
    """One training iteration's collective mix under each CC policy.

    ``batched=True`` stacks the policies into one product policy and runs
    the whole comparison as a single vmapped dispatch
    (``SweepRunner.run_policy_axis``): one compile, one call, B = number
    of policies.  ``batched=False`` runs serially per policy (each run
    early-exits).  The default (None) picks per scenario via
    ``SweepRunner.policy_axis_pays_off`` — batched where the vmap axis
    vectorizes (accelerators), serial on CPU.
    Reports don't consume queue timelines, so recording is off; pass a
    shared ``runner`` to reuse compiled engines across calls."""
    # oversubscription=2.0 == the seed clos() default of 8 spines
    fab = fabric if fabric is not None else \
        (topo if topo is not None
         else FabricSpec(family="clos", n_racks=2, nodes_per_rack=2,
                         gpus_per_node=8, oversubscription=2.0))
    cfg = cfg or EngineConfig(dt=2e-6, max_steps=4000, max_extends=6,
                              queue_stride=0)
    runner = runner or SweepRunner(cfg)
    workload = HLOReplaySpec(tuple(ops), tuple(mesh_shape), tuple(axis_of_op))
    policies = tuple(policies or cc_mod.ALL_POLICIES)
    topo_b, sched, _ = ScenarioSpec(fabric=fab, workload=workload,
                                    policy=policies).build()
    if batched is None:
        batched = runner.policy_axis_pays_off()
    if batched:
        batch = runner.run_policy_axis(topo_b, sched, policies, cfg=cfg)
        return [PredictReport(batch.policy_of(i),
                              float(batch.completion_time[i]),
                              float(batch.pause_count[i].sum()),
                              bool(batch.finished[i]),
                              extend_exhausted=bool(
                                  batch.extend_exhausted[i]))
                for i in range(batch.n)]
    specs = [ScenarioSpec(fabric=fab, workload=workload, policy=p)
             for p in policies]
    out = []
    for res in runner.run_specs(specs, cfg=cfg):
        out.append(PredictReport(res.meta["policy"], res.completion_time,
                                 float(res.pause_count.sum()), res.finished,
                                 extend_exhausted=res.extend_exhausted))
    return out
