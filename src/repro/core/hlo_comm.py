"""Extract the collective-communication schedule from compiled HLO.

This is the bridge between the *real* training framework and the paper's
network simulator: ``extract(lowered_text)`` parses every collective op
(all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute)
out of the (possibly SPMD-partitioned) HLO, with operand bytes and replica
group structure, so ``core.predict`` can replay an architecture's actual
communication under each CC policy — generalizing the paper's DLRM-only
analysis to every arch in the zoo.  The same byte counts feed the
§Roofline collective term.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  %all-reduce.5 = f32[1024,512] all-reduce(...), replica_groups={{0,1},{2,3}}
_OP_RE = re.compile(
    r"=\s*((?:\(|)[a-z0-9\[\],{}() ]+?)\s+"
    r"(all-reduce-start|all-gather-start|reduce-scatter|all-to-all|"
    r"all-reduce|all-gather|collective-permute-start|collective-permute)"
    r"\(", re.I)

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{(\{[0-9, ]+\}(?:,\{[0-9, ]+\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """Frozen (hashable) so HLO-replay scenario workloads can key the
    schedule memoization in ``scenario.ScenarioSpec.build``."""
    kind: str
    bytes_total: int        # sum of operand bytes (global, all shards)
    group_size: int         # participants per replica group
    n_groups: int
    count: int = 1          # duplicates (e.g. inside while loops x trip count)


def _shape_bytes(sig: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(sig):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def extract(hlo_text: str, trip_counts: dict | None = None) -> list[CollectiveOp]:
    """Parse collective ops out of HLO text.

    Note on loops: ops inside a `while` body appear once in the text; the
    scan trip count multiplies the actual traffic.  We detect the enclosing
    computation name and multiply by ``trip_counts[name]`` when provided;
    benchmarks pass the layer count for the scan body.
    """
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        sig, kind = m.groups()
        kind = kind.replace("-start", "")
        nbytes = _shape_bytes(sig)
        gm = _GROUPS_RE.search(line)
        if gm:
            groups = gm.group(1)
            first = groups.split("},")[0].strip("{}")
            gsize = len([x for x in first.split(",") if x.strip()])
            ngroups = groups.count("{")
        else:
            im = _GROUPS_IOTA_RE.search(line)
            if im:
                ngroups, gsize = int(im.group(1)), int(im.group(2))
            else:
                gsize, ngroups = 0, 1
        ops.append(CollectiveOp(kind, nbytes, gsize, ngroups))
    return ops


def summarize(ops: list[CollectiveOp]) -> dict:
    """Aggregate bytes by collective kind."""
    agg: dict = defaultdict(float)
    for op in ops:
        agg[op.kind] += op.bytes_total * op.count
    agg["total"] = sum(v for k, v in agg.items() if k != "total")
    return dict(agg)


def collective_link_bytes(ops: list[CollectiveOp], algo_bytes_factor: dict | None = None) -> float:
    """Wire bytes actually moved per chip group, using standard algorithm
    costs: ring all-reduce moves 2(n-1)/n x data, all-gather/reduce-scatter
    (n-1)/n, all-to-all (n-1)/n, permute 1x."""
    factors = {"all-reduce": lambda n: 2 * (n - 1) / max(n, 1),
               "all-gather": lambda n: (n - 1) / max(n, 1),
               "reduce-scatter": lambda n: (n - 1) / max(n, 1),
               "all-to-all": lambda n: (n - 1) / max(n, 1),
               "collective-permute": lambda n: 1.0}
    if algo_bytes_factor:
        factors.update(algo_bytes_factor)
    total = 0.0
    for op in ops:
        n = max(op.group_size, 1)
        total += op.bytes_total * op.count * factors[op.kind](n)
    return total
