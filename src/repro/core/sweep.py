"""Sweep-scale driving of the fluid engine: shape-bucketed compile reuse
and vmap-batched CC-parameter sweeps.

The paper's result set is a sweep (CC policies x collectives x topologies,
Figs 3-11); the engine in ``repro.core.engine`` compiles one executable per
``(policy logic, EngineConfig, static plan)``.  ``SweepRunner`` adds the
two missing pieces for running *many* scenarios fast:

* **shape buckets** — flow/group counts are padded up to the next power of
  two (inert padding, see ``engine._prep``), so schedules of similar size
  share one compiled executable instead of retracing per scenario;
* **vmap batching** — ``run_batch`` stacks CC parameter pytrees of one
  policy family on a leading axis and runs the whole population in a
  single compiled call (``jax.vmap`` over the stepping loop), which turns
  grid sweeps and population-based autotuning into one dispatch.

Batched runs never record the per-device queue timeline (it is a
per-member ``(T, D)`` buffer); use a plain ``run`` for Fig 5-7 style plots.

CPU note: vmap batching pays off where per-op dispatch overhead dominates
— small/medium scenarios such as population autotuning and CC grid sweeps
(measured ~2-4.5x over serial at B=8-16 on the dev container; see
``benchmarks/bench_engine.py``).  For very large gather-bound scenarios on
CPU the batched stepping loses its early-exit advantage (it runs until the
*slowest* member finishes and computes both sides of the done-gate), so
prefer serial ``run``/``run_policies`` there; on accelerator backends the
batch dimension vectorizes fully.

    runner = SweepRunner(EngineConfig(dt=2e-6, max_steps=4000, queue_stride=0))
    results = runner.run_policies(topo, sched, ["pfc", "dcqcn", "hpcc"])
    batch = runner.grid(topo, sched, get_policy("dcqcn"),
                        {"rai_frac": [0.01, 0.03, 0.1],
                         "timer": [25e-6, 55e-6, 105e-6]})
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax
import numpy as np

from repro.core import cc as cc_mod
from repro.core.cc import Policy
from repro.core.engine import (EngineConfig, Results, Simulator, _init_carry,
                               _make_run, _next_pow2, _policy_cache_key)


def _bucket(n: int, lo: int = 32) -> int:
    return max(lo, _next_pow2(max(n, 1)))


@dataclasses.dataclass
class BatchResults:
    """One vmapped sweep over B stacked CC parameter sets."""
    policy: str
    params: dict                  # stacked leaves, shape (B,)
    completion_time: np.ndarray   # (B,)
    t_finish: np.ndarray          # (B, F)
    pause_count: np.ndarray       # (B, D)
    delivered: np.ndarray         # (B, F)
    soft_cost: np.ndarray         # (B,)
    finished: np.ndarray          # (B,) bool

    @property
    def n(self) -> int:
        return len(self.completion_time)

    def best(self) -> int:
        """Index of the fastest *finished* member (lowest completion)."""
        if not self.finished.any():
            raise ValueError("no sweep member finished within the step "
                             "budget; raise max_steps/max_extends")
        ct = np.where(self.finished, self.completion_time, np.inf)
        return int(np.argmin(ct))

    def param_set(self, i: int) -> dict:
        return {k: float(np.asarray(v)[i]) for k, v in self.params.items()}


_BATCH_CACHE: dict = {}


def _compiled_batch(policy: Policy, cfg: EngineConfig, plan):
    """vmapped (pp, stacked_params) -> stacked finals, cached like
    ``engine.compiled_run`` so same-shaped scenarios share the executable."""
    key = (_policy_cache_key(policy), cfg, plan)
    if key not in _BATCH_CACHE:
        run = _make_run(policy, cfg, plan, early_exit=True)

        def one(pp, params):
            carry = _init_carry(pp, plan, policy, cfg)
            carry, steps = run(carry, pp, params)
            return {"t_finish": carry["t_finish"], "done": carry["done"],
                    "pause_count": carry["pause_count"],
                    "delivered": carry["delivered"], "soft": carry["soft"],
                    "steps": steps}

        _BATCH_CACHE[key] = jax.jit(jax.vmap(one, in_axes=(None, 0)))
    return _BATCH_CACHE[key]


class SweepRunner:
    """Compile-once, run-many driver for ``repro.core.engine``.

    One instance caches prepared scenarios (``_prep`` output) by object
    identity and leans on the engine's global compile cache for the jitted
    stepping loops, so sweeping P policies over S same-shaped scenarios
    compiles each policy once, not P x S times.
    """

    # prepared-scenario cache bound: entries hold (Fp, MAXHOP)-scale arrays,
    # so cap the count and evict FIFO; compiled executables live in the
    # engine's global cache and survive eviction
    MAX_SIMS = 64

    def __init__(self, cfg: EngineConfig | None = None, bucket: bool = True):
        self.cfg = cfg or EngineConfig()
        self.bucket = bucket
        self._sims: dict = {}

    @staticmethod
    def _scenario_key(topo, sched):
        """Content fingerprint, so schedules rebuilt per call (e.g. the
        DLRM iteration in figs 10/11) still hit the cache."""
        h = hashlib.sha1()
        for a in (sched.path, sched.size, sched.group, sched.dep,
                  sched.delay, topo.cap, topo.lat, topo.src_dev,
                  topo.dst_dev, topo.ecn_on, topo.fabric,
                  topo.dev_is_switch, topo.dev_buf):
            h.update(np.ascontiguousarray(a).tobytes())
        return (topo.name, sched.n_flows, sched.n_groups, h.hexdigest())

    # -- scenario preparation ------------------------------------------------
    def simulator(self, topo, sched, policy: Policy,
                  cfg: EngineConfig | None = None) -> Simulator:
        cfg = cfg or self.cfg
        key = (self._scenario_key(topo, sched), cfg,
               _policy_cache_key(policy))
        sim = self._sims.get(key)
        if sim is None:
            pf = _bucket(sched.n_flows) if self.bucket else None
            pg = _bucket(sched.n_groups, lo=8) if self.bucket else None
            sim = Simulator(topo, sched, policy, cfg,
                            pad_flows=pf, pad_groups=pg)
            while len(self._sims) >= self.MAX_SIMS:
                self._sims.pop(next(iter(self._sims)))
            self._sims[key] = sim
        return sim

    # -- single runs ---------------------------------------------------------
    def run(self, topo, sched, policy: Policy | str,
            cc_params: dict | None = None,
            cfg: EngineConfig | None = None) -> Results:
        policy = cc_mod.get_policy(policy) if isinstance(policy, str) else policy
        return self.simulator(topo, sched, policy, cfg).run(cc_params)

    def run_policies(self, topo, sched, policies=None,
                     cfg: EngineConfig | None = None) -> list[Results]:
        """One scenario under each CC policy (the paper's per-figure loop)."""
        out = []
        for p in (policies or cc_mod.ALL_POLICIES):
            out.append(self.run(topo, sched, p, cfg=cfg))
        return out

    # -- batched parameter sweeps -------------------------------------------
    def run_batch(self, topo, sched, policy: Policy | str,
                  stacked_params: dict) -> BatchResults:
        """Simulate B parameter sets of one policy family in one call.

        ``stacked_params`` maps param name -> length-B array; missing params
        are broadcast from the policy defaults.  Queue timelines are never
        recorded for batched runs (per-member buffers).
        """
        policy = cc_mod.get_policy(policy) if isinstance(policy, str) else policy
        policy.check_tunable(stacked_params)
        B = len(np.asarray(next(iter(stacked_params.values()))))
        full = {k: np.asarray(stacked_params.get(k, np.full(B, float(v))),
                              np.float32)
                for k, v in policy.params.items()}
        cfg = dataclasses.replace(self.cfg, queue_stride=0)
        sim = self.simulator(topo, sched, policy, cfg)
        out = _compiled_batch(policy, cfg, sim.plan)(sim.pp, full)
        F, G = sim.plan.n_flows, sim.plan.n_groups
        del G
        t_fin = np.asarray(out["t_finish"])[:, :F]
        done = np.asarray(out["done"])[:, :F]
        ct = np.max(np.where(np.isfinite(t_fin), t_fin, 0.0), axis=1)
        return BatchResults(
            policy=policy.name, params=full,
            completion_time=ct, t_finish=t_fin,
            pause_count=np.asarray(out["pause_count"]),
            delivered=np.asarray(out["delivered"])[:, :F],
            soft_cost=np.asarray(out["soft"]),
            finished=done.all(axis=1),
        )

    def grid(self, topo, sched, policy: Policy | str,
             param_grid: dict) -> BatchResults:
        """Full-factorial sweep: {param: [values...]} -> one batched run."""
        keys = list(param_grid)
        mesh = np.meshgrid(*[np.asarray(param_grid[k], np.float32)
                             for k in keys], indexing="ij")
        return self.run_batch(topo, sched, policy,
                              {k: m.reshape(-1) for k, m in zip(keys, mesh)})
