"""Sweep-scale driving of the fluid engine: shape-bucketed compile reuse
and vmap-batched CC-parameter x fabric-parameter sweeps.

The paper's result set is a sweep (CC policies x collectives x topologies
x fabric tuning, Figs 3-11); the engine in ``repro.core.engine`` compiles
one executable per ``(policy logic, EngineConfig, static plan)``.
``SweepRunner`` adds the pieces for running *many* scenarios fast:

* **shape buckets** — flow/group counts are padded up to the next power of
  two (inert padding, see ``engine._prep``), so schedules of similar size
  share one compiled executable instead of retracing per scenario;
* **vmap batching** — ``run_batch`` stacks CC parameter pytrees *and*
  ``FabricParams`` leaves (ECN kmin/kmax/pmax, PFC xoff/xon) of one policy
  family on a leading axis and runs the whole population in a single
  compiled call, which turns joint CC x fabric grids and population-based
  autotuning into one dispatch — zero recompiles after warmup;
* **scenario specs** — ``run_spec`` / ``run_specs`` / ``grid_spec`` accept
  the declarative ``repro.core.scenario.ScenarioSpec``, so drivers list
  scenarios instead of hand-assembling topology + schedule + policy;
* **a batched policy axis** — ``run_policy_axis`` stacks several CC
  policies into one product policy (``cc.stack_policies``: superset state
  + ``lax.switch`` on a traced selector) and runs the whole comparison as
  ONE vmapped dispatch; ``grid(..., policy_axis=[...])`` crosses that axis
  with CC-param and fabric grids, so the paper's policy-comparison figures
  are a single compiled call with zero recompiles after warmup;
* **spec-driven grids** — ``grid_from_spec(policy, n_points)`` generates
  grid axes from each policy's declared ``ParamSpec`` ranges (log/linear
  spacing, integer rounding) instead of hand-picked value lists;
* **sharded grid scale-out** — ``SweepRunner(mesh="auto")`` lays the
  grid/batch axis over a 1-D device mesh (``shard_map`` on top of the
  per-lane vmap; lanes are embarrassingly parallel, so a B-lane grid
  costs ~B/n_devices lane-times) with round-robin lane placement,
  edge-repeat padding for non-divisible grids (masked back out of
  ``BatchResults``), and streamed fixed-size chunking for grids larger
  than device memory (chunk i+1 dispatches before chunk i's results are
  pulled to host; per-device working set is bounded by
  ``lane_state_bytes x chunk/n_devices`` regardless of grid size).  On a
  CPU-only host, test with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.  The sharded
  and vmap paths are allclose-equivalent (rtol 1e-5); ``mesh=None``
  (the default) is bitwise the historical path.

Batched runs never record the per-device queue timeline (it is a
per-member ``(T, D)`` buffer); use a plain ``run`` for Fig 5-7 style plots.

Backend note: vmap batching pays off where per-op dispatch overhead
dominates — small/medium scenarios such as population autotuning and CC
grid sweeps (measured ~2-4.5x over serial at B=8-16 on the dev container;
see ``benchmarks/bench_engine.py``).  For very large gather-bound
scenarios on CPU the batched stepping loses its early-exit advantage (it
runs until the *slowest* member finishes and computes both sides of the
done-gate); on accelerator backends the batch dimension vectorizes fully.
``batch_pays_off``/``policy_axis_pays_off`` decide serial-vs-batched from
the active backend's crossover table: ``calibrate_backend()`` measures it
(serial vs batched at a few probe sizes, cached per backend, JSON records
for BENCH_engine.json), ``DEFAULT_CROSSOVERS`` is the uncalibrated
fallback.

    runner = SweepRunner(EngineConfig(dt=2e-6, max_steps=4000, queue_stride=0))
    results = runner.run_policies(topo, sched, ["pfc", "dcqcn", "hpcc"])
    batch = runner.grid(topo, sched, get_policy("dcqcn"),
                        {"rai_frac": [0.01, 0.03, 0.1]},
                        fabric_grid={"kmin": [100e3, 400e3],
                                     "xoff": [0.5e6, 1e6, 2e6]})
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import warnings

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro.common.sharding import resolve_grid_mesh
from repro.core import cc as cc_mod
from repro.core.cc import Policy, stack_policies
from repro.core.engine import (EngineConfig, FabricParams, Results, Simulator,
                               _as_fabric, _cfg_static, _init_carry,
                               _make_run, _next_pow2, _policy_cache_key)
from repro.core.faults import (FaultSpec, LaneStatus, _as_fault,
                               classify_lane, is_faulty)


def _resolve(policy) -> Policy:
    return cc_mod.get_policy(policy) if isinstance(policy, str) else policy


def _bucket(n: int, lo: int = 32) -> int:
    return max(lo, _next_pow2(max(n, 1)))


@dataclasses.dataclass
class BatchResults:
    """One vmapped sweep over B stacked (CC params, FabricParams,
    FaultSpec) sets, with per-lane run-health status: a diverged,
    deadlocked or budget-exhausted lane is isolated and reported while
    the healthy lanes complete normally."""
    policy: str
    params: dict                  # stacked CC leaves, shape (B,)
    fabric: dict                  # stacked FabricParams leaves, (B,) or (B,C)
    completion_time: np.ndarray   # (B,)
    t_finish: np.ndarray          # (B, F)
    pause_count: np.ndarray       # (B, D)
    delivered: np.ndarray         # (B, F)
    soft_cost: np.ndarray         # (B,)
    finished: np.ndarray          # (B,) bool
    policy_axis: tuple = ()       # per-member policy label (policy sweeps)
    fault: dict = dataclasses.field(default_factory=dict)  # FaultSpec leaves
    diverged: np.ndarray | None = None        # (B,) non-finite lane, frozen
    deadlock_step: np.ndarray | None = None   # (B,) first pause-cycle step
    storm_step: np.ndarray | None = None      # (B,) first pause-storm step
    extend_exhausted: np.ndarray | None = None  # (B,) budget ran out

    @property
    def n(self) -> int:
        return len(self.completion_time)

    @property
    def deadlocked(self) -> np.ndarray:
        """(B,) bool: a PFC pause-graph cycle was detected in that lane."""
        if self.deadlock_step is None:
            return np.zeros(self.n, bool)
        return self.deadlock_step >= 0

    def lane_status(self) -> list[LaneStatus]:
        """Per-lane health as typed ``faults.LaneStatus`` (a ``str``
        subclass, so ``== "ok"`` / JSON / CSV consumers are unchanged).
        A deadlocked-but-finished lane still reads ``DEADLOCKED`` (the
        cycle resolved only because flows drained)."""
        dead = self.deadlocked
        div = (np.zeros(self.n, bool) if self.diverged is None
               else self.diverged)
        return [classify_lane(bool(div[i]), bool(dead[i]),
                              bool(self.finished[i]))
                for i in range(self.n)]

    def best(self) -> int:
        """Index of the fastest *finished* member (lowest completion)."""
        if not self.finished.any():
            raise ValueError("no sweep member finished within the step "
                             "budget; raise max_steps/max_extends")
        ct = np.where(self.finished, self.completion_time, np.inf)
        return int(np.argmin(ct))

    def policy_of(self, i: int) -> str:
        """Policy label of member ``i`` (== ``policy`` without an axis)."""
        if self.policy_axis:
            return self.policy_axis[int(np.asarray(
                self.params["_which"])[i])]
        return self.policy

    def param_set(self, i: int) -> dict:
        return {k: float(np.asarray(v)[i]) for k, v in self.params.items()}

    def fabric_set(self, i: int) -> FabricParams:
        return FabricParams(**{k: np.asarray(v)[i]
                               for k, v in self.fabric.items()})

    def fault_set(self, i: int) -> FaultSpec:
        """The FaultSpec lane ``i`` ran under (inert spec if no faults)."""
        if not self.fault:
            return FaultSpec()
        return FaultSpec(**{k: np.asarray(v)[i]
                            for k, v in self.fault.items()})


_BATCH_CACHE: dict = {}
_SHARD_CACHE: dict = {}
# compiled-callable cache bounds, FIFO like the scenario cache
# (SweepRunner.MAX_SIMS): a long campaign across many shapes/policies
# would otherwise accumulate jitted executables without limit.  Eviction
# counts surface in compile_stats()["evictions"].
BATCH_CACHE_MAX = 64
SHARD_CACHE_MAX = 64
_CACHE_EVICTIONS = {"batch": 0, "shard": 0}


def _cache_put(cache: dict, key, value, kind: str, bound: int):
    while len(cache) >= max(bound, 1):
        cache.pop(next(iter(cache)))
        _CACHE_EVICTIONS[kind] += 1
    cache[key] = value
    return value


# unhealthy-lane warning dedupe: one warning per (policy, status-kind set)
# per process, so a 1000-chunk campaign hitting the same unhealthy regime
# every chunk warns once instead of 1000 times.  reset_unhealthy_warnings
# re-arms (tests asserting on the warning call it between runs).
_UNHEALTHY_WARNED: set = set()


def reset_unhealthy_warnings() -> None:
    """Re-arm the deduplicated unhealthy-lane ``RuntimeWarning``."""
    _UNHEALTHY_WARNED.clear()


def _fmt_lane_indices(idx: list, cap: int = 8) -> str:
    head = ", ".join(str(i) for i in idx[:cap])
    return f"[{head}{', ...' if len(idx) > cap else ''}]"


def _warn_unhealthy_lanes(batch: "BatchResults", B: int) -> None:
    unhealthy = [(i, s) for i, s in enumerate(batch.lane_status())
                 if s is not LaneStatus.OK]
    if not unhealthy:
        return
    key = (batch.policy, frozenset(s for _, s in unhealthy))
    if key in _UNHEALTHY_WARNED:
        return
    _UNHEALTHY_WARNED.add(key)
    by_status: dict = {}
    for i, s in unhealthy:
        by_status.setdefault(s, []).append(i)
    detail = "; ".join(f"{s}: lanes {_fmt_lane_indices(idx)}"
                       for s, idx in by_status.items())
    warnings.warn(
        f"{len(unhealthy)}/{B} sweep lanes unhealthy ({detail}); healthy "
        "lanes completed normally — inspect BatchResults.lane_status(). "
        "Further identical warnings for this (policy, status) combination "
        "are suppressed (sweep.reset_unhealthy_warnings() re-arms).",
        RuntimeWarning, stacklevel=3)


def _one_lane(policy: Policy, cfg: EngineConfig, plan, faulty: bool):
    """The per-lane body both batch paths vmap over: build a fresh carry,
    run the jitted stepping loop (which donates it internally) and keep
    only the per-lane finals."""
    run = _make_run(policy, cfg, plan, early_exit=True, faulty=faulty)

    def one(pp, params, fab, flt):
        carry = _init_carry(pp, plan, policy, cfg, params, faulty)
        carry, steps = run(carry, pp, params, fab, flt)
        out = {"t_finish": carry["t_finish"], "done": carry["done"],
               "pause_count": carry["pause_count"],
               "delivered": carry["delivered"], "soft": carry["soft"],
               "steps": steps, "diverged": carry["diverged"],
               "deadlock_step": carry["deadlock_step"],
               "storm_step": carry["storm_step"]}
        if faulty:
            out["lost"] = carry["lost"]
        return out

    return one


def _compiled_batch(policy: Policy, cfg: EngineConfig, plan,
                    faulty: bool = False):
    """vmapped (pp, stacked_params, stacked_fabric, stacked_fault) ->
    stacked finals, cached like ``engine.compiled_run`` so same-shaped
    scenarios share the executable (fabric scalars on cfg are normalized
    out of the key; ``faulty`` keys the fault-injection compile path)."""
    key = (_policy_cache_key(policy), _cfg_static(cfg), plan, faulty)
    fn = _BATCH_CACHE.get(key)
    if fn is None:
        one = _one_lane(policy, cfg, plan, faulty)
        fn = _cache_put(_BATCH_CACHE, key,
                        jax.jit(jax.vmap(one, in_axes=(None, 0, 0, 0))),
                        "batch", BATCH_CACHE_MAX)
    return fn


def _mesh_key(mesh):
    return (tuple(mesh.axis_names),
            tuple(d.id for d in np.asarray(mesh.devices).reshape(-1)))


def _compiled_sharded_batch(policy: Policy, cfg: EngineConfig, plan,
                            faulty: bool, mesh):
    """The vmapped batch laid over a 1-D device mesh via ``shard_map``:
    each device runs the per-lane vmap over its local block of lanes (the
    lanes are embarrassingly parallel — no cross-device collectives), so a
    B-lane grid costs ~B/n_devices lane-times of wall clock.  The lane
    axis of every stacked input/output is sharded on the mesh's grid
    axis; ``pp`` (the prepared scenario) is replicated.  Cached alongside
    ``_BATCH_CACHE`` with the mesh identity in the key."""
    key = (_policy_cache_key(policy), _cfg_static(cfg), plan, faulty,
           _mesh_key(mesh))
    fn = _SHARD_CACHE.get(key)
    if fn is None:
        one = _one_lane(policy, cfg, plan, faulty)
        vm = jax.vmap(one, in_axes=(None, 0, 0, 0))
        axis = mesh.axis_names[0]
        lanes = PartitionSpec(axis)
        sharded = shard_map(vm, mesh=mesh,
                            in_specs=(PartitionSpec(), lanes, lanes, lanes),
                            out_specs=lanes, check_rep=False)
        fn = _cache_put(_SHARD_CACHE, key, jax.jit(sharded),
                        "shard", SHARD_CACHE_MAX)
    return fn


def compile_stats() -> dict:
    """Compile-cache counters (for asserting zero-recompile sweeps)."""
    from repro.core import engine as engine_mod

    def n_exec(fns):
        return sum(f._cache_size() for f in fns
                   if hasattr(f, "_cache_size"))

    return {
        "run_cache": len(engine_mod._RUN_CACHE),
        "batch_cache": len(_BATCH_CACHE),
        "shard_cache": len(_SHARD_CACHE),
        "compiled_executables": n_exec(engine_mod._RUN_CACHE.values())
        + n_exec(_BATCH_CACHE.values())
        + n_exec(_SHARD_CACHE.values()),
        "evictions": dict(_CACHE_EVICTIONS),
    }


def grid_from_spec(policy: Policy | str, n_points: int = 3,
                   keys: list | None = None) -> dict:
    """Generate grid axes from a policy's declared ``ParamSpec`` ranges.

    Each selected tunable, *bounded* param gets ``n_points`` values
    spanning [lo, hi] — geometrically spaced where the spec declares
    ``scale="log"``, linearly otherwise, rounded + deduplicated for
    integer params.  Feed the result straight to ``SweepRunner.grid``:

        runner.grid(topo, sched, "dcqcn", grid_from_spec("dcqcn", 3,
                                                         ["rai_frac", "g"]))
    """
    policy = _resolve(policy)
    if keys is None:
        keys = [k for k, s in policy.spec.items()
                if not s.init_baked and s.bounded and not k.startswith("_")]
    else:
        policy.check_tunable(keys)
    axes = {}
    for k in keys:
        s = policy.param_spec(k)
        if not s.bounded:
            raise ValueError(f"{policy.name} param {k!r} declares no "
                             "lo/hi bounds; pass explicit grid values")
        if s.scale == "log":
            vals = np.geomspace(s.lo, s.hi, n_points)
        else:
            vals = np.linspace(s.lo, s.hi, n_points)
        if s.integer:
            vals = np.unique(np.round(vals))
        axes[k] = [float(v) for v in vals]
    if not axes:
        raise ValueError(f"{policy.name} has no bounded tunable params")
    return axes


def _stack_fabric(base: FabricParams, stacked: dict | None, B: int) -> FabricParams:
    """Stack FabricParams leaves on a leading B axis; leaves absent from
    ``stacked`` broadcast the base value.  Stacked leaves may be (B,)
    scalars-per-member or (B, N_LINK_CLASSES) per-class arrays."""
    stacked = stacked or {}
    FabricParams.check_fields(stacked)
    leaves = {}
    for f in FabricParams.FIELDS:
        if f in stacked:
            v = np.asarray(stacked[f], np.float32)
            if v.shape[0] != B:
                raise ValueError(f"fabric param {f!r} has leading dim "
                                 f"{v.shape[0]}, expected batch {B}")
        else:
            b = np.asarray(getattr(base, f), np.float32)
            v = np.broadcast_to(b, (B,) + b.shape)
        leaves[f] = v
    return FabricParams(**leaves)


def _stack_fault(base: FaultSpec, stacked: dict | None, B: int) -> FaultSpec:
    """Stack FaultSpec leaves on a leading B axis, mirroring
    ``_stack_fabric``: leaves absent from ``stacked`` broadcast the base
    value; stacked leaves may be (B,) scalars-per-member or
    (B, N_LINK_CLASSES) per-class arrays."""
    stacked = stacked or {}
    FaultSpec.check_fields(stacked)
    leaves = {}
    for f in FaultSpec.FIELDS:
        if f in stacked:
            v = np.asarray(stacked[f], np.float32)
            if v.shape[0] != B:
                raise ValueError(f"fault param {f!r} has leading dim "
                                 f"{v.shape[0]}, expected batch {B}")
        else:
            b = np.asarray(getattr(base, f), np.float32)
            v = np.broadcast_to(b, (B,) + b.shape)
        leaves[f] = v
    return FaultSpec(**leaves)


def stack_policy_axis(policies=None, cc_overrides: list | None = None):
    """Build the vmappable policy-axis inputs without dispatching.

    Stacks ``policies`` into one product policy (``cc.stack_policies``)
    and assembles its per-lane selector params: the traced ``_which``
    column, the paired ``_wire`` factors, and member-namespaced
    ``"<policy>.<param>"`` columns for any ``cc_overrides`` (positionally
    aligned with ``policies``; only lane i reads member i's params).
    Returns ``(stacked_policy, params, labels)`` — ready for
    ``run_batch(..., policy_axis=labels)``.  ``run_policy_axis`` is the
    dispatching wrapper; the campaign layer uses this to journal and
    re-dispatch policy-axis chunks independently."""
    members = [_resolve(p) for p in (policies or cc_mod.ALL_POLICIES)]
    stacked_pol = stack_policies(members)
    labels = stacked_pol.members
    B = len(members)
    params = {
        "_which": np.arange(B, dtype=np.float32),
        "_wire": np.asarray([m.wire_factor for m in members],
                            np.float32),
    }
    if cc_overrides:
        if len(cc_overrides) != B:
            raise ValueError(f"cc_overrides has {len(cc_overrides)} "
                             f"entries for {B} policies")
        for i, (lab, m, over) in enumerate(
                zip(labels, members, cc_overrides)):
            if not over:
                continue
            m.check_tunable(over)
            for k, v in over.items():
                key = f"{lab}.{k}"
                col = params.get(key)
                if col is None:
                    col = np.full(B, float(m.params[k]), np.float32)
                col[i] = float(v)     # only lane i reads member i's params
                params[key] = col
    return stacked_pol, params, tuple(labels)


# -- backend calibration ----------------------------------------------------

_INF = float("inf")

# Fallback crossover tables (largest n_flows at which the batched path
# still wins wall-clock) used before any measurement has run on a backend.
# "sweep" = same-policy vmapped parameter sweep vs a serial loop;
# "policy_axis" = stacked lax.switch product policy vs per-policy runs;
# "sharded" = the shard_map grid layout vs the single-device vmap (only
# measurable with >1 device; unlisted -> inf, i.e. shard whenever a mesh
# was configured).  CPU numbers are from BENCH_engine.json on the dev
# container (the sweep wins 4-5x below ~2k flows and loses 0.3x on the
# 7936-flow All-Reduce; the policy axis loses at every measured CPU
# scale).  Backends not listed (TPU/GPU) vectorize the batch axis fully,
# so batching always pays off there (inf).
DEFAULT_CROSSOVERS: dict = {
    "cpu": {"sweep": 2048.0, "policy_axis": 0.0},
}


@dataclasses.dataclass(frozen=True)
class BackendCalibration:
    """Serial-vs-batched crossover table for one JAX backend, either
    measured (``calibrate_backend``) or the ``DEFAULT_CROSSOVERS``
    fallback.  ``crossover[kind]`` is the largest flow count at which the
    batched path still wins: ``inf`` = batching always pays off, ``0.0`` =
    never."""
    backend: str
    source: str = "default"            # "default" | "measured"
    crossover: dict = dataclasses.field(default_factory=dict)
    probes: tuple = ()                 # (kind, n_flows, serial_s, batched_s)

    def pays_off(self, kind: str, n_flows: int | None = None) -> bool:
        """Should the batched path run for ``kind`` at ``n_flows``?  With
        ``n_flows=None`` (scenario-independent callers) batching is
        recommended only when it wins at *every* scale."""
        thr = float(self.crossover.get(kind, _INF))
        if n_flows is None:
            return thr == _INF
        return n_flows <= thr

    def record(self) -> dict:
        """JSON-safe dict for BENCH_engine.json (inf encoded as "inf")."""
        enc = {k: ("inf" if float(v) == _INF else float(v))
               for k, v in self.crossover.items()}
        return {"backend": self.backend, "source": self.source,
                "crossover": enc,
                "probes": [{"kind": k, "n_flows": n, "serial_s": s,
                            "batched_s": b}
                           for k, n, s, b in self.probes]}


_CALIBRATION: dict = {}
# backends for which the on-disk table must NOT be consulted: either the
# load was already attempted once, or reset_calibration() pinned the
# process back to the defaults ("*" = every backend)
_NO_DISK: set = set()


def calibration_cache_path(backend: str | None = None,
                           cache_dir: str | None = None) -> str:
    """Where ``calibrate_backend`` persists its measured table
    (``$REPRO_CACHE_DIR/repro_calibration_<backend>.json``, default
    ``.cache/``) so fresh processes warm-start instead of re-measuring."""
    backend = backend or jax.default_backend()
    cache_dir = cache_dir or os.environ.get("REPRO_CACHE_DIR", ".cache")
    return os.path.join(cache_dir, f"repro_calibration_{backend}.json")


def save_calibration(cal: BackendCalibration,
                     path: str | None = None) -> str | None:
    """Persist a measured calibration to disk (JSON; inf encoded).  Best
    effort: an unwritable cache dir is silently skipped (returns None).
    Written tmp-file + atomic rename, so a run killed mid-write leaves
    the previous table intact instead of a truncated JSON."""
    path = path or calibration_cache_path(cal.backend)
    rec = cal.record()
    rec["saved_at"] = time.time()
    rec["jax"] = jax.__version__
    rec["n_devices"] = len(jax.devices())
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(rec, f, indent=1)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        return None
    return path


def load_calibration(backend: str | None = None, path: str | None = None,
                     max_age_days: float | None = None
                     ) -> BackendCalibration | None:
    """Load a persisted calibration, or None when absent/stale/invalid.

    A table is rejected when it was measured under a different jax
    version or device count (both change the crossover), or — with
    ``max_age_days`` — when older than that.  A corrupt or truncated
    file (e.g. left by a killed run predating the atomic-rename save)
    is logged and ignored, never raised — a stale warm-start cache must
    not take down the first sweep of a fresh process."""
    backend = backend or jax.default_backend()
    path = path or calibration_cache_path(backend)
    try:
        with open(path) as f:
            rec = json.load(f)
    except OSError:
        return None                     # absent cache: the normal cold start
    except ValueError:
        warnings.warn(f"ignoring corrupt calibration cache {path} "
                      "(unparseable JSON; re-measure or delete it)",
                      RuntimeWarning, stacklevel=2)
        return None
    try:
        if rec.get("backend") != backend:
            return None
        if rec.get("jax") != jax.__version__:
            return None
        if rec.get("n_devices") != len(jax.devices()):
            return None
        if max_age_days is not None:
            age = time.time() - float(rec.get("saved_at", 0.0))
            if age > max_age_days * 86400.0:
                return None
        crossover = {k: (_INF if v == "inf" else float(v))
                     for k, v in rec.get("crossover", {}).items()}
        probes = tuple((p["kind"], int(p["n_flows"]), float(p["serial_s"]),
                        float(p["batched_s"])) for p in rec.get("probes", ()))
    except Exception:                   # valid JSON, wrong shape/types
        warnings.warn(f"ignoring malformed calibration cache {path} "
                      "(unexpected record shape; re-measure or delete it)",
                      RuntimeWarning, stacklevel=2)
        return None
    return BackendCalibration(backend=backend,
                              source=rec.get("source", "measured"),
                              crossover=crossover, probes=probes)


def get_calibration(backend: str | None = None) -> BackendCalibration:
    """The active crossover table for ``backend`` (default: the running
    JAX backend): the cached ``calibrate_backend`` measurement if one
    exists, else a table persisted to disk by a previous process
    (``calibration_cache_path``; disable with REPRO_CALIBRATION_CACHE=0),
    else the ``DEFAULT_CROSSOVERS`` entry (unlisted backends get inf
    thresholds — batching always on, accelerator behavior)."""
    backend = backend or jax.default_backend()
    cal = _CALIBRATION.get(backend)
    if (cal is None and "*" not in _NO_DISK and backend not in _NO_DISK
            and os.environ.get("REPRO_CALIBRATION_CACHE", "1") != "0"):
        _NO_DISK.add(backend)          # one load attempt per process
        cal = load_calibration(backend)
        if cal is not None:
            _CALIBRATION[backend] = cal
    if cal is None:
        table = dict(DEFAULT_CROSSOVERS.get(
            backend, {"sweep": _INF, "policy_axis": _INF}))
        cal = BackendCalibration(backend=backend, crossover=table)
    return cal


def set_calibration(cal: BackendCalibration) -> None:
    """Install a crossover table for ``cal.backend`` (e.g. one loaded from
    a previous BENCH_engine.json record)."""
    _CALIBRATION[cal.backend] = cal


def reset_calibration(backend: str | None = None) -> None:
    """Drop cached calibrations (all backends when ``backend`` is None),
    reverting ``get_calibration`` to the defaults — the on-disk table is
    not reconsulted until the process restarts (tests rely on reset
    meaning *defaults*, not *whatever a previous bench run persisted*)."""
    if backend is None:
        _CALIBRATION.clear()
        _NO_DISK.add("*")
    else:
        _CALIBRATION.pop(backend, None)
        _NO_DISK.add(backend)


def _measure_crossover(kind: str, n_flows: int, B: int,
                       cfg: EngineConfig) -> tuple:
    """Default calibration probe: time a serial loop against one batched
    dispatch for a ``kind`` sweep on a 1D All-Reduce of ~``n_flows``
    flows — the autotune/grid-sweep regime these heuristics actually
    gate (bytes scale with ranks so the step budget stays occupied and
    the comparison is not dominated by trivial-run early-exit).  Returns
    ``(actual_n_flows, serial_s, batched_s)``, both sides timed
    post-warmup (compiles excluded)."""
    import time as _time

    from repro.core.collectives import allreduce_1d
    from repro.core.topology import single_switch

    # allreduce_1d over R ranks with 4 chunks ~= 8*R*(R-1) flows
    R = max(2, int(round(0.5 + (0.25 + n_flows / 8.0) ** 0.5)))
    topo = single_switch(R)
    sched = allreduce_1d(topo, list(range(R)), 1e6 * R)
    runner = SweepRunner(cfg)
    if kind == "sweep":
        policy = cc_mod.get_policy("dcqcn")
        scale = np.linspace(0.5, 2.0, B).astype(np.float32)

        def serial():
            for s in scale:
                runner.run(topo, sched, policy,
                           dict(policy.params, rai_frac=float(0.03 * s)))

        def batched():
            runner.run_batch(topo, sched, policy,
                             {"rai_frac": 0.03 * scale})
    elif kind == "policy_axis":
        pols = list(cc_mod.ALL_POLICIES)[:max(2, B)]

        def serial():
            runner.run_policies(topo, sched, pols)

        def batched():
            runner.run_policy_axis(topo, sched, pols)
    elif kind == "sharded":
        # the shard_map grid layout vs the single-device vmap, same B-lane
        # sweep on both sides; "serial" here means the un-sharded vmap
        sharded = SweepRunner(cfg, mesh="auto")
        if sharded.mesh is None:
            raise RuntimeError("sharded calibration needs >1 JAX device "
                               "(emulate: XLA_FLAGS="
                               "--xla_force_host_platform_device_count=8)")
        policy = cc_mod.get_policy("dcqcn")
        Bs = max(B, sharded.n_mesh_devices)
        scale = np.linspace(0.5, 2.0, Bs).astype(np.float32)
        stacked = {"rai_frac": 0.03 * scale}

        def serial():
            runner.run_batch(topo, sched, policy, stacked)

        def batched():
            sharded.run_batch(topo, sched, policy, stacked)
    else:
        raise ValueError(f"unknown calibration kind: {kind!r}")

    out = []
    for fn in (serial, batched):
        fn()                                    # warmup: compile
        t0 = _time.perf_counter()
        fn()
        out.append(_time.perf_counter() - t0)
    return sched.n_flows, out[0], out[1]


def calibrate_backend(probe_flows=(90, 1806), B: int = 6,
                      cfg: EngineConfig | None = None,
                      kinds=None,
                      backend: str | None = None,
                      persist: bool = True,
                      _measure=None) -> BackendCalibration:
    """Measure the serial-vs-batched wall-clock crossover on the running
    backend and cache it; ``SweepRunner.batch_pays_off`` /
    ``policy_axis_pays_off`` / ``sharded_pays_off`` consult the cached
    table from then on.

    For each ``kind`` the batched path is timed against the serial loop at
    each probe size; the crossover is the geometric mean of the largest
    winning and smallest losing probe (all probes win -> inf, all lose ->
    0.0).  ``kinds=None`` probes "sweep" and "policy_axis", plus "sharded"
    (shard_map grid layout vs single-device vmap) when more than one JAX
    device is visible.  The measured table is persisted to
    ``calibration_cache_path()`` (``persist=False`` to skip) so later
    processes warm-start via ``get_calibration`` instead of re-measuring.
    ``_measure(kind, n_flows, B, cfg)`` is injectable for tests and
    deterministic benchmarks; ``BackendCalibration.record()`` gives the
    JSON form ``benchmarks/bench_engine.py`` writes to BENCH_engine.json.
    """
    backend = backend or jax.default_backend()
    cfg = cfg or EngineConfig(dt=2e-6, max_steps=600, max_extends=1,
                              queue_stride=0)
    if kinds is None:
        kinds = ("sweep", "policy_axis")
        if len(jax.devices()) > 1:
            kinds += ("sharded",)
    measure = _measure or _measure_crossover
    probes, table = [], {}
    for kind in kinds:
        wins, losses = [], []
        for n in probe_flows:
            nf, serial_s, batched_s = measure(kind, n, B, cfg)
            probes.append((kind, int(nf), float(serial_s), float(batched_s)))
            (wins if batched_s < serial_s else losses).append(float(nf))
        if not losses:
            table[kind] = _INF
        elif not wins:
            table[kind] = 0.0
        else:
            table[kind] = float((max(wins) * min(losses)) ** 0.5)
    cal = BackendCalibration(backend=backend, source="measured",
                             crossover=table, probes=tuple(probes))
    set_calibration(cal)
    if persist and _measure is None:    # injected probes are synthetic —
        save_calibration(cal)           # never persist them to disk
    return cal


class SweepRunner:
    """Compile-once, run-many driver for ``repro.core.engine``.

    One instance caches prepared scenarios (``_prep`` output) by content
    fingerprint and leans on the engine's global compile cache for the
    jitted stepping loops, so sweeping P policies over S same-shaped
    scenarios compiles each policy once, not P x S times.
    """

    # prepared-scenario cache bound: entries hold (Fp, MAXHOP)-scale arrays,
    # so cap the count and evict FIFO; compiled executables live in the
    # engine's global cache and survive eviction
    MAX_SIMS = 64

    # chunk_lanes="auto": stream grids bigger than this many lanes per
    # device in fixed-size chunks (per-device working set stays bounded
    # regardless of grid size)
    AUTO_CHUNK_PER_DEVICE = 256

    def __init__(self, cfg: EngineConfig | None = None, bucket: bool = True,
                 mesh=None, chunk_lanes: int | str | None = "auto",
                 dispatch_hook=None):
        self.cfg = cfg or EngineConfig()
        self.bucket = bucket
        self._sims: dict = {}
        # mesh=None -> single-device vmap (the historical path, bitwise
        # unchanged); "auto" -> all local devices when >1; int/Mesh -> as
        # given.  See resolve_grid_mesh.
        self.mesh = resolve_grid_mesh(mesh)
        self.chunk_lanes = chunk_lanes
        # called as dispatch_hook(lo, hi, B) immediately before each lane
        # chunk is dispatched — the campaign layer's injectable failure
        # point (an exception raised here aborts the dispatch exactly like
        # an XLA OOM/compile failure would) and kill/progress probe
        self.dispatch_hook = dispatch_hook

    def _pre_dispatch(self, lo: int, hi: int, B: int) -> None:
        if self.dispatch_hook is not None:
            self.dispatch_hook(lo, hi, B)

    @property
    def n_mesh_devices(self) -> int:
        """Devices the grid axis is laid over (1 == un-sharded vmap)."""
        if self.mesh is None:
            return 1
        return int(np.asarray(self.mesh.devices).size)

    def _chunk_size(self, B: int) -> int:
        """Lanes per dispatched chunk: a multiple of the mesh size, ``B``
        itself (padded up) when no chunking applies."""
        n_dev = self.n_mesh_devices
        pad_to = -(-B // n_dev) * n_dev                   # ceil to mesh
        if self.chunk_lanes in (None, 0):
            return pad_to
        if self.chunk_lanes == "auto":
            limit = self.AUTO_CHUNK_PER_DEVICE * n_dev
        else:
            limit = max(int(self.chunk_lanes), 1)
            limit = -(-limit // n_dev) * n_dev            # ceil to mesh
        return min(pad_to, limit)

    @staticmethod
    def _scenario_key(topo, sched):
        """Content fingerprint, so schedules rebuilt per call (e.g. the
        DLRM iteration in figs 10/11) still hit the cache."""
        h = hashlib.sha1()
        for a in (sched.path, sched.size, sched.group, sched.dep,
                  sched.delay, topo.cap, topo.lat, topo.src_dev,
                  topo.dst_dev, topo.ecn_on, topo.fabric, topo.link_class,
                  topo.dev_is_switch, topo.dev_buf):
            h.update(np.ascontiguousarray(a).tobytes())
        return (topo.name, sched.n_flows, sched.n_groups, h.hexdigest())

    # -- scenario preparation ------------------------------------------------
    def simulator(self, topo, sched, policy: Policy,
                  cfg: EngineConfig | None = None) -> Simulator:
        cfg = cfg or self.cfg
        # fabric scalars are traced (passed per run), so configs differing
        # only there share one prepared Simulator
        key = (self._scenario_key(topo, sched), _cfg_static(cfg),
               _policy_cache_key(policy))
        sim = self._sims.get(key)
        if sim is None:
            pf = _bucket(sched.n_flows) if self.bucket else None
            pg = _bucket(sched.n_groups, lo=8) if self.bucket else None
            sim = Simulator(topo, sched, policy, cfg,
                            pad_flows=pf, pad_groups=pg)
            while len(self._sims) >= self.MAX_SIMS:
                self._sims.pop(next(iter(self._sims)))
            self._sims[key] = sim
        return sim

    # -- single runs ---------------------------------------------------------
    def run(self, topo, sched, policy: Policy | str,
            cc_params: dict | None = None,
            cfg: EngineConfig | None = None,
            fabric_params: FabricParams | None = None,
            fault_spec: FaultSpec | None = None) -> Results:
        policy = _resolve(policy)
        cfg = cfg or self.cfg
        # resolve the fabric from the *caller's* cfg: the cached Simulator
        # may have been built under a different default
        fab = _as_fabric(fabric_params, cfg)
        return self.simulator(topo, sched, policy, cfg).run(
            cc_params, fabric_params=fab, fault_spec=_as_fault(fault_spec))

    def run_policies(self, topo, sched, policies=None,
                     cfg: EngineConfig | None = None,
                     fabric_params: FabricParams | None = None) -> list[Results]:
        """One scenario under each CC policy, serially — full ``Results``
        per policy (queue timelines included); ``run_policy_axis`` runs the
        same comparison as one vmapped dispatch."""
        out = []
        for p in (policies or cc_mod.ALL_POLICIES):
            out.append(self.run(topo, sched, p, cfg=cfg,
                                fabric_params=fabric_params))
        return out

    def batch_pays_off(self, sched) -> bool:
        """Should a *same-policy* parameter sweep over this scenario run
        batched (one vmapped dispatch) or serial?  Decided from the active
        backend's crossover table — the cached ``calibrate_backend``
        measurement, or ``DEFAULT_CROSSOVERS`` when uncalibrated."""
        return get_calibration().pays_off("sweep", sched.n_flows)

    def policy_axis_pays_off(self, sched=None) -> bool:
        """Like ``batch_pays_off`` but for the stacked policy axis, which
        additionally evaluates *every* member's update per lane (vmapped
        ``lax.switch`` runs all branches).  Called without ``sched`` the
        axis is recommended only where it wins at every measured scale: on
        CPU it loses wall-clock everywhere (BENCH_engine.json policy_axis)
        — the win there is architectural (one compile, zero recompiles
        across policy x param x fabric grids), not wall-clock."""
        return get_calibration().pays_off(
            "policy_axis", None if sched is None else sched.n_flows)

    def sharded_pays_off(self, sched=None) -> bool:
        """Would laying the grid axis over the device mesh beat one
        device's vmap?  Trivially False without a mesh; otherwise decided
        from the backend crossover table (kind ``"sharded"``, default:
        always — real multi-device backends parallelize lanes).  Like
        ``batch_pays_off`` this is *advice for drivers* deciding whether
        to construct a runner with a mesh; ``run_batch`` itself never
        second-guesses an explicitly configured mesh (the emulated-device
        testing recipe depends on that).  Wall-clock choice only: both
        paths are allclose-equivalent."""
        if self.mesh is None:
            return False
        return get_calibration().pays_off(
            "sharded", None if sched is None else sched.n_flows)

    def lane_state_bytes(self, topo, sched, policy: Policy | str,
                         cfg: EngineConfig | None = None,
                         faulty: bool = False) -> int:
        """Device bytes one sweep lane's stepping carry occupies (via
        ``jax.eval_shape`` — nothing is allocated).  The chunked-streaming
        memory bound per device is ``chunk_size / n_devices * lane_state_bytes``
        plus the replicated scenario, independent of total grid size."""
        policy = _resolve(policy)
        cfg = dataclasses.replace(cfg or self.cfg, queue_stride=0)
        sim = self.simulator(topo, sched, policy, cfg)
        params = {k: np.float32(v) for k, v in policy.params.items()}
        shapes = jax.eval_shape(
            lambda pp, par: _init_carry(pp, sim.plan, policy, cfg, par,
                                        faulty),
            sim.pp, params)
        return int(sum(np.prod(s.shape) * s.dtype.itemsize
                       for s in jax.tree.leaves(shapes)))

    # -- the batched policy axis --------------------------------------------
    def run_policy_axis(self, topo, sched, policies=None,
                        cc_overrides: list | None = None,
                        cfg: EngineConfig | None = None,
                        fabric_params: FabricParams | None = None,
                        stacked_fabric: dict | None = None,
                        fault_spec: FaultSpec | None = None,
                        stacked_fault: dict | None = None) -> BatchResults:
        """The paper's per-figure policy comparison as ONE vmapped dispatch.

        Stacks ``policies`` into a product policy (``cc.stack_policies``)
        and vmaps over its traced ``_which`` selector: B = len(policies)
        lanes, each simulating one member, sharing a single compiled
        executable.  ``cc_overrides`` optionally gives a per-member
        cc_params dict (positionally aligned with ``policies``);
        ``stacked_fabric`` may additionally stack per-lane FabricParams
        leaves (length B, aligned with the policy lanes).  The result's
        ``policy_axis``/``policy_of`` label each lane.
        """
        stacked_pol, params, labels = stack_policy_axis(policies,
                                                        cc_overrides)
        return self.run_batch(topo, sched, stacked_pol, params,
                              stacked_fabric=stacked_fabric,
                              fabric_params=fabric_params, cfg=cfg,
                              policy_axis=tuple(labels),
                              stacked_fault=stacked_fault,
                              fault_spec=fault_spec)

    # -- declarative scenarios ----------------------------------------------
    def run_spec(self, spec, cfg: EngineConfig | None = None) -> Results:
        """Simulate one ``ScenarioSpec`` (shape-bucketed + compile-cached)."""
        if isinstance(spec.policy, (tuple, list)):
            raise ValueError(
                "spec declares a policy axis (tuple policy); run it batched "
                "via grid_spec/run_policy_axis, or pick one member")
        topo, sched, policy = spec.build()
        cc = None
        if spec.cc_params:
            policy.check_tunable(spec.cc_params)
            cc = dict(policy.params, **spec.cc_params)
        return self.run(topo, sched, policy, cc_params=cc, cfg=cfg,
                        fabric_params=spec.fabric_params,
                        fault_spec=spec.fault_spec)

    def run_specs(self, specs, cfg: EngineConfig | None = None) -> list:
        """Simulate a list of ``ScenarioSpec``s; same-shaped specs share
        compiled engines via the shape-bucketed scenario cache.  A
        tuple-policy spec (``scenario_matrix(stacked=True)``) runs its
        policy axis as one batched — and, with a mesh, sharded — dispatch
        and contributes a ``BatchResults`` entry instead of ``Results``."""
        return [self.grid_spec(s, cfg=cfg)
                if isinstance(s.policy, (tuple, list))
                else self.run_spec(s, cfg=cfg) for s in specs]

    def grid_spec(self, spec, param_grid: dict | None = None,
                  fabric_grid: dict | None = None,
                  cfg: EngineConfig | None = None,
                  fault_grid: dict | None = None) -> BatchResults:
        """Full-factorial CC x fabric x fault grid on one ``ScenarioSpec``.
        A spec whose ``policy`` is a tuple/list sweeps the policy axis too
        (one vmapped policy x CC-param x fabric x fault dispatch); the
        spec's ``fault_spec`` broadcasts to every lane not covered by
        ``fault_grid`` axes."""
        if isinstance(spec.policy, (tuple, list)):
            topo, sched, _ = spec.build()
            return self.grid(topo, sched, None, param_grid, fabric_grid,
                             fabric_params=spec.fabric_params,
                             cc_params=spec.cc_params, cfg=cfg,
                             policy_axis=list(spec.policy),
                             fault_grid=fault_grid,
                             fault_spec=spec.fault_spec)
        topo, sched, policy = spec.build()
        return self.grid(topo, sched, policy, param_grid, fabric_grid,
                         fabric_params=spec.fabric_params,
                         cc_params=spec.cc_params, cfg=cfg,
                         fault_grid=fault_grid,
                         fault_spec=spec.fault_spec)

    # -- batched parameter sweeps -------------------------------------------
    def _dispatch_lanes(self, policy: Policy, cfg: EngineConfig, sim,
                        full: dict, fab: FabricParams, flt: FaultSpec,
                        faulty: bool, B: int) -> dict:
        """Dispatch B stacked lanes, gather stacked finals to host numpy.

        Un-sharded (``mesh=None``) and fitting one chunk, this is exactly
        the historical single-dispatch vmap — bitwise unchanged.  With a
        mesh, the lane axis is laid over the devices via ``shard_map``
        with ROUND-ROBIN lane placement: grid lanes arrive sorted along
        sweep axes, so blocks of consecutive lanes share a regime and
        block placement would pile a slow region onto one device; the
        round-robin permutation interleaves them (lane i -> device
        i % n_dev), then the inverse permutation restores input order on
        the way out.  Grids larger than one chunk stream: chunk i+1 is
        dispatched (JAX dispatch is async) before chunk i's buffers are
        pulled to host, overlapping transfer with compute, and only one
        chunk of lane state lives on the devices at a time
        (``lane_state_bytes`` x chunk/n_dev per device).  The trailing
        chunk is padded by edge-repeating the final lane — inert work
        whose results are dropped before returning, so callers always see
        exactly B lanes in input order.
        """
        # an explicitly configured mesh is an explicit choice: it is used
        # unconditionally (the emulated-device testing recipe depends on
        # that).  sharded_pays_off is *advice for drivers* deciding
        # whether to construct a mesh, mirroring batch_pays_off — run_batch
        # never second-guesses its caller.
        lanes = (full, fab, flt)
        if self.mesh is None:
            fn = _compiled_batch(policy, cfg, sim.plan, faulty)
            chunk = self._chunk_size(B)
            if chunk >= B:                        # one dispatch, no padding
                self._pre_dispatch(0, B, B)
                out = fn(sim.pp, *lanes)
                return jax.tree.map(np.asarray, out)
            parts, pending = [], None
            for lo in range(0, B, chunk):
                hi = min(lo + chunk, B)
                take = np.arange(lo, hi)
                if hi - lo < chunk:               # edge-repeat trailing pad
                    take = np.concatenate(
                        [take, np.full(chunk - (hi - lo), hi - 1)])
                self._pre_dispatch(lo, hi, B)
                got = fn(sim.pp, *jax.tree.map(lambda a: a[take], lanes))
                if pending is not None:
                    lo0, hi0, out0 = pending
                    parts.append(jax.tree.map(
                        lambda a: np.asarray(a)[:hi0 - lo0], out0))
                pending = (lo, hi, got)
            lo0, hi0, out0 = pending
            parts.append(jax.tree.map(
                lambda a: np.asarray(a)[:hi0 - lo0], out0))
            return jax.tree.map(
                lambda *xs: np.concatenate(xs, axis=0), *parts)
        n_dev = self.n_mesh_devices
        chunk = self._chunk_size(B)
        fn = _compiled_sharded_batch(policy, cfg, sim.plan, faulty,
                                     self.mesh)
        # within a chunk: permute so block-sharding over the mesh assigns
        # device d the round-robin lanes {d, d+n_dev, ...}; inv undoes it
        order = np.arange(chunk).reshape(-1, n_dev).T.reshape(-1)
        inv = np.argsort(order)
        parts, pending = [], None
        for lo in range(0, B, chunk):
            hi = min(lo + chunk, B)
            take = np.arange(lo, hi)
            if hi - lo < chunk:                   # edge-repeat trailing pad
                take = np.concatenate(
                    [take, np.full(chunk - (hi - lo), hi - 1)])
            self._pre_dispatch(lo, hi, B)
            got = fn(sim.pp, *jax.tree.map(lambda a: a[take[order]], lanes))
            if pending is not None:               # stream: gather the chunk
                lo0, hi0, out0 = pending          # dispatched *last* round
                parts.append(jax.tree.map(
                    lambda a: np.asarray(a)[inv][:hi0 - lo0], out0))
            pending = (lo, hi, got)
        lo0, hi0, out0 = pending
        parts.append(jax.tree.map(
            lambda a: np.asarray(a)[inv][:hi0 - lo0], out0))
        if len(parts) == 1:
            return parts[0]
        return jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *parts)

    def run_batch(self, topo, sched, policy: Policy | str,
                  stacked_params: dict | None = None,
                  stacked_fabric: dict | None = None,
                  fabric_params: FabricParams | None = None,
                  cc_params: dict | None = None,
                  cfg: EngineConfig | None = None,
                  policy_axis: tuple = (),
                  stacked_fault: dict | None = None,
                  fault_spec: FaultSpec | None = None) -> BatchResults:
        """Simulate B (CC params, FabricParams, FaultSpec) sets in one
        vmapped call.

        ``stacked_params`` maps CC param name -> length-B array;
        ``stacked_fabric`` maps FabricParams field -> (B,) or (B, C) array;
        ``stacked_fault`` maps FaultSpec field -> (B,) or (B, C) array.
        Missing CC params broadcast from the policy defaults (overridden by
        ``cc_params``); missing fabric fields broadcast from
        ``fabric_params`` (default: the runner config's scalars); missing
        fault fields broadcast from ``fault_spec`` (default: inert).  Queue
        timelines are never recorded for batched runs (per-member buffers).
        ``policy_axis`` carries the per-lane policy labels when ``policy``
        is a stacked product policy (see ``run_policy_axis``).

        Lane isolation: a diverged (non-finite) lane freezes in place, a
        deadlocked or budget-exhausted lane is flagged, and the healthy
        lanes complete normally — see ``BatchResults.lane_status``.
        """
        policy = _resolve(policy)
        stacked_params = stacked_params or {}
        policy.check_tunable(stacked_params)
        if cc_params:
            policy.check_tunable(cc_params)
        sizes = [len(np.asarray(v)) for v in stacked_params.values()]
        sizes += [np.asarray(v).shape[0] for v in (stacked_fabric or {}).values()]
        sizes += [np.asarray(v).shape[0] for v in (stacked_fault or {}).values()]
        if not sizes:
            raise ValueError("empty batch: provide stacked_params, "
                             "stacked_fabric and/or stacked_fault")
        if len(set(sizes)) > 1:
            raise ValueError(f"inconsistent batch sizes {sorted(set(sizes))}")
        B = sizes[0]
        base_cc = dict(policy.params, **(cc_params or {}))
        full = {k: np.asarray(stacked_params.get(k, np.full(B, float(v))),
                              np.float32)
                for k, v in base_cc.items()}
        cfg = dataclasses.replace(cfg or self.cfg, queue_stride=0)
        fab = _stack_fabric(_as_fabric(fabric_params, cfg), stacked_fabric, B)
        flt = _stack_fault(_as_fault(fault_spec), stacked_fault, B)
        faulty = is_faulty(flt)
        sim = self.simulator(topo, sched, policy, cfg)
        out = self._dispatch_lanes(policy, cfg, sim, full, fab, flt,
                                   faulty, B)
        F = sim.plan.n_flows
        t_fin = np.asarray(out["t_finish"])[:, :F]
        done = np.asarray(out["done"])[:, :F]
        ct = np.max(np.where(np.isfinite(t_fin), t_fin, 0.0), axis=1)
        finished = done.all(axis=1)
        diverged = np.asarray(out["diverged"])
        deadlock_step = np.asarray(out["deadlock_step"])
        storm_step = np.asarray(out["storm_step"])
        extend_exhausted = ~finished & ~diverged
        batch = BatchResults(
            policy=policy.name, params=full,
            fabric={k: np.asarray(getattr(fab, k))
                    for k in FabricParams.FIELDS},
            completion_time=ct, t_finish=t_fin,
            pause_count=np.asarray(out["pause_count"]),
            delivered=np.asarray(out["delivered"])[:, :F],
            soft_cost=np.asarray(out["soft"]),
            finished=finished,
            policy_axis=tuple(policy_axis),
            fault=({k: np.asarray(getattr(flt, k))
                    for k in FaultSpec.FIELDS} if faulty else {}),
            diverged=diverged, deadlock_step=deadlock_step,
            storm_step=storm_step, extend_exhausted=extend_exhausted,
        )
        _warn_unhealthy_lanes(batch, B)
        return batch

    def grid(self, topo, sched, policy: Policy | str | None = None,
             param_grid: dict | None = None,
             fabric_grid: dict | None = None,
             fabric_params: FabricParams | None = None,
             cc_params: dict | None = None,
             cfg: EngineConfig | None = None,
             policy_axis: list | None = None,
             fault_grid: dict | None = None,
             fault_spec: FaultSpec | None = None) -> BatchResults:
        """Full-factorial joint sweep: CC ``{param: [values...]}`` x fabric
        ``{field: [values...]}`` x fault ``{field: [values...]}`` -> ONE
        vmapped batched run.

        Fabric/fault grid axes may list scalars or per-class arrays (each
        entry one grid point).  With several grids given, the batch
        enumerates the full cross product — e.g. 3 kmin x 3 xoff x 4 CC
        points = B=36 in a single compiled dispatch; a ``fault_grid`` like
        ``{"loss_rate": [0, 1e-5, 1e-3], "gbn": [0, 1]}`` crosses fault
        regimes into the same dispatch (non-grid fault fields broadcast
        from ``fault_spec``).

        ``policy_axis`` adds the *policy* as a grid dimension: the named
        policies are stacked into one product policy and the cross product
        gains a lane per member (policy x CC-param x fabric x fault, still
        one dispatch).  With a policy axis, ``policy`` must be None and
        ``param_grid`` keys must be member-namespaced (``"dcqcn.rai_frac"``
        — only that member's lanes respond to the axis).
        """
        param_grid = param_grid or {}
        fabric_grid = fabric_grid or {}
        fault_grid = fault_grid or {}
        FaultSpec.check_fields(fault_grid)
        for a, b, what in (((param_grid, fabric_grid, "CC and fabric")),
                           ((param_grid, fault_grid, "CC and fault")),
                           ((fabric_grid, fault_grid, "fabric and fault"))):
            overlap = set(a) & set(b)
            if overlap:
                raise ValueError(f"params {sorted(overlap)} appear in both "
                                 f"the {what} grids")
        labels, wires = (), None
        if policy_axis is not None:
            if policy is not None:
                raise ValueError("pass either policy or policy_axis, "
                                 "not both")
            members = [_resolve(p) for p in policy_axis]
            wires = np.asarray([m.wire_factor for m in members], np.float32)
            policy = stack_policies(members)
            labels = policy.members
            bad = {k for k in param_grid if "." not in k}
            if bad:
                raise ValueError(
                    f"param_grid keys {sorted(bad)} are not member-"
                    "namespaced; with a policy_axis use '<policy>.<param>' "
                    f"(members: {list(labels)})")
        elif policy is None:
            raise ValueError("policy is required without a policy_axis")
        axes = [np.asarray(v, np.float32)
                for v in list(param_grid.values()) + list(fabric_grid.values())
                + list(fault_grid.values())]
        names = list(param_grid) + list(fabric_grid) + list(fault_grid)
        if policy_axis is not None:
            names.append("_which")
            axes.append(np.arange(len(labels), dtype=np.float32))
        if not axes:
            raise ValueError("empty grid")
        # index-space meshgrid so per-class (point, C)-shaped fabric/fault
        # axes enumerate points along axis 0
        idx = np.meshgrid(*[np.arange(len(a)) for a in axes], indexing="ij")
        flat = [i.reshape(-1) for i in idx]
        stacked = {k: axes[j][flat[j]] for j, k in enumerate(names)}
        stacked_cc = {k: stacked[k] for k in names
                      if k not in fabric_grid and k not in fault_grid}
        if wires is not None:
            # the wire factor is paired with the selected member, never an
            # independent axis
            stacked_cc["_wire"] = wires[stacked["_which"].astype(np.int64)]
        return self.run_batch(
            topo, sched, policy, stacked_cc,
            stacked_fabric={k: stacked[k] for k in fabric_grid},
            fabric_params=fabric_params, cc_params=cc_params, cfg=cfg,
            policy_axis=labels,
            stacked_fault={k: stacked[k] for k in fault_grid},
            fault_spec=fault_spec)
