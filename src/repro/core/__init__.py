"""The paper's contribution: workload -> system -> network simulation of
RoCE congestion control for distributed training (see DESIGN.md)."""
from repro.core.cc import (  # noqa: F401
    ALL_POLICIES,
    FlowCtx,
    ParamSpec,
    Policy,
    Signals,
    get_policy,
    policy_table_markdown,
    stack_policies,
)
from repro.core.collectives import (  # noqa: F401
    COLLECTIVES,
    allreduce_1d,
    allreduce_2d,
    allreduce_hring,
    allreduce_ring,
    alltoall,
    get_collective,
    incast,
    register_collective,
)
from repro.core.faults import (  # noqa: F401
    FAULT_PARAM_SPECS,
    RECOVERY_MODES,
    FaultSpec,
    LaneStatus,
    classify_lane,
)
from repro.core.campaign import (  # noqa: F401
    CampaignError,
    CampaignFingerprintMismatch,
    CampaignResult,
    CampaignTask,
    run_campaign,
    smoke_tasks,
)
from repro.core.engine import (  # noqa: F401
    FABRIC_PARAM_SPECS,
    EngineConfig,
    FabricParams,
    Results,
    Simulator,
    simulate,
)
from repro.core.scenario import (  # noqa: F401
    TOPOLOGIES,
    CollectiveSpec,
    FabricSpec,
    IncastSpec,
    ScenarioSpec,
    register_topology,
    scenario_matrix,
)
from repro.core.sweep import (  # noqa: F401
    BackendCalibration,
    BatchResults,
    SweepRunner,
    calibrate_backend,
    compile_stats,
    get_calibration,
    grid_from_spec,
    load_calibration,
    save_calibration,
    stack_policy_axis,
)
from repro.core.topology import LINK_CLASSES, clos, single_switch  # noqa: F401
