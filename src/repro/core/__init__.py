"""The paper's contribution: workload -> system -> network simulation of
RoCE congestion control for distributed training (see DESIGN.md)."""
from repro.core.cc import ALL_POLICIES, get_policy  # noqa: F401
from repro.core.collectives import (  # noqa: F401
    allreduce_1d,
    allreduce_2d,
    alltoall,
    incast,
)
from repro.core.engine import EngineConfig, Results, Simulator, simulate  # noqa: F401
from repro.core.sweep import BatchResults, SweepRunner  # noqa: F401
from repro.core.topology import clos, single_switch  # noqa: F401
