"""The paper's contribution: workload -> system -> network simulation of
RoCE congestion control for distributed training (see DESIGN.md)."""
from repro.core.cc import ALL_POLICIES, get_policy  # noqa: F401
from repro.core.collectives import (  # noqa: F401
    COLLECTIVES,
    allreduce_1d,
    allreduce_2d,
    allreduce_hring,
    allreduce_ring,
    alltoall,
    get_collective,
    incast,
    register_collective,
)
from repro.core.engine import (  # noqa: F401
    EngineConfig,
    FabricParams,
    Results,
    Simulator,
    simulate,
)
from repro.core.scenario import (  # noqa: F401
    TOPOLOGIES,
    CollectiveSpec,
    FabricSpec,
    IncastSpec,
    ScenarioSpec,
    register_topology,
    scenario_matrix,
)
from repro.core.sweep import BatchResults, SweepRunner, compile_stats  # noqa: F401
from repro.core.topology import LINK_CLASSES, clos, single_switch  # noqa: F401
