"""Resilient campaign execution: kill-safe, self-healing, accountable
paper-scale sweeps on top of ``SweepRunner``.

The full policy x tuned-param x fabric x fault atlas is hours of compute;
one OOM, preemption or diverged lane must not throw it away.  Hoefler et
al. (PAPERS.md, "Issues at Hyperscale") argue at-scale runs have to treat
failure as the common case — a platform that *simulates* fault tolerance
should itself be fault tolerant.  ``run_campaign`` adds exactly that
layer:

* **durable chunk journal** — a campaign is content-fingerprinted (task
  scenarios + stacked grids + EngineConfig + jax version); every
  dispatched chunk's results are written atomically (tmp-file +
  ``os.replace``) under ``<out>/<campaign>/journal/``, and
  ``resume=True`` replays completed chunks from disk, so a SIGKILL
  mid-campaign loses at most one chunk of work and the merged results
  are bitwise-identical to an uninterrupted run;
* **retry ladder with graceful degradation** — a failed chunk dispatch
  (XLA OOM, compile failure, device loss under a mesh) is retried with
  exponential backoff down an explicit ladder: halve the chunk -> force
  ``step_impl="jnp"`` -> abandon the mesh for single-device vmap ->
  serial per-lane runs.  Each demotion is recorded in the manifest,
  never silent, and sticks for the task's remaining chunks;
* **lane quarantine** — lanes that finish unhealthy (diverged,
  deadlocked, budget-exhausted; see ``faults.LaneStatus``) are
  re-dispatched once with a relaxed step budget
  (``max_steps * quarantine_relax``) instead of poisoning the summary.
  (float64 re-runs are not eligible: the engine state is pinned float32
  end-to-end, so budget relaxation is the only lever.)  The retry is
  journaled too, and only lanes that come back healthy are patched in;
* **deadline / per-chunk watchdog** — a wall-clock deadline is checked
  before every dispatch, and ``chunk_timeout_s`` runs each dispatch
  under a watchdog thread; either trips a clean checkpoint-and-exit
  with a partial manifest instead of a truncated CSV;
* **structured manifest** — ``manifest.json`` carries the full failure
  taxonomy: per-chunk attempts/demotions/wall, quarantined lanes with
  before/after status, uncovered lanes, and the coverage fraction, so a
  committed atlas states exactly what it covers and what it dropped.

Usage::

    tasks = [CampaignTask("dcqcn", topo, sched, "dcqcn",
                          stacked_params={"rai_frac": grid})]
    res = run_campaign(tasks, name="atlas_smoke", resume=True,
                       deadline_s=3600, max_retries=3)
    res.results["dcqcn"]      # merged BatchResults (NaN rows = uncovered)
    res.manifest["coverage"]  # 1.0 when nothing was dropped

``scripts/run_campaign.py`` is the CLI (``--resume``, ``--deadline``,
``--max-retries``); ``benchmarks/atlas.py`` routes through this layer.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import threading
import time
import warnings

import jax
import numpy as np

from repro.core.engine import (EngineConfig, FabricParams, _as_fabric,
                               _cfg_static, resolve_step_impl)
from repro.core.faults import (FaultSpec, LaneStatus, _as_fault,
                               classify_lane, is_faulty)
from repro.core.sweep import (BatchResults, SweepRunner, _resolve,
                              _stack_fabric, _stack_fault)

JOURNAL_DIR = "journal"
MANIFEST = "manifest.json"
FINGERPRINT = "fingerprint.json"

# the per-lane result arrays a chunk journals (exactly the array fields
# of BatchResults; params/fabric/fault are re-derived from the task spec
# at merge time, so the journal stays compact)
RESULT_KEYS = ("completion_time", "t_finish", "pause_count", "delivered",
               "soft_cost", "finished", "diverged", "deadlock_step",
               "storm_step", "extend_exhausted")

# graceful-degradation ladder, applied cumulatively and in order; rungs
# that cannot apply in the current environment (already on jnp, no mesh)
# are skipped when the ladder is instantiated per task
DEMOTION_LADDER = ("half_chunk", "jnp_step", "no_mesh", "serial")


class CampaignError(RuntimeError):
    """Base for campaign-layer failures."""


class CampaignFingerprintMismatch(CampaignError):
    """The on-disk journal belongs to a different campaign definition."""


class ChunkTimeout(CampaignError):
    """A chunk dispatch exceeded ``chunk_timeout_s`` under the watchdog."""


# ---------------------------------------------------------------------------
# campaign definition
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CampaignTask:
    """One journaled unit of a campaign: a ``run_batch`` call's inputs.

    ``stacked_*`` dicts follow ``SweepRunner.run_batch`` exactly (CC
    param / FabricParams field / FaultSpec field -> length-B arrays); at
    least one must be non-empty.  ``policy`` may be a name, a ``Policy``
    or a stacked product policy (then set ``policy_axis`` to its member
    labels, e.g. via ``sweep.stack_policy_axis``).  ``cfg`` overrides
    the campaign's EngineConfig for this task only.
    """
    name: str
    topo: object
    sched: object
    policy: object
    stacked_params: dict | None = None
    stacked_fabric: dict | None = None
    stacked_fault: dict | None = None
    cc_params: dict | None = None
    fabric_params: FabricParams | None = None
    fault_spec: FaultSpec | None = None
    policy_axis: tuple = ()
    cfg: EngineConfig | None = None

    @property
    def n_lanes(self) -> int:
        sizes = [np.asarray(v).shape[0]
                 for d in (self.stacked_params, self.stacked_fabric,
                           self.stacked_fault) if d
                 for v in d.values()]
        if not sizes:
            raise CampaignError(
                f"task {self.name!r} has no stacked axes; campaigns journal "
                "batched lanes (provide stacked_params / stacked_fabric / "
                "stacked_fault)")
        if len(set(sizes)) > 1:
            raise CampaignError(f"task {self.name!r} has inconsistent lane "
                                f"counts {sorted(set(sizes))}")
        return sizes[0]

    def _sliced(self, idx) -> tuple[dict, dict, dict]:
        """The three stacked dicts restricted to lanes ``idx`` (a slice
        or an index array)."""
        return tuple({k: np.asarray(v)[idx] for k, v in (d or {}).items()}
                     for d in (self.stacked_params, self.stacked_fabric,
                               self.stacked_fault))


def _sanitize(name: str) -> str:
    safe = re.sub(r"[^A-Za-z0-9._-]+", "_", name).strip("._")
    if not safe:
        raise CampaignError(f"unusable task/campaign name {name!r}")
    return safe


def _policy_token(policy) -> dict:
    """A cross-process-stable identity for a policy: name, wire factor,
    default params, member labels.  (``engine._policy_cache_key`` is NOT
    usable here — it embeds ``__code__`` objects whose repr carries
    memory addresses.)"""
    policy = _resolve(policy)
    return {"name": policy.name,
            "wire_factor": float(policy.wire_factor),
            "params": {k: float(v)
                       for k, v in sorted(policy.params.items())},
            "members": list(getattr(policy, "members", ()) or ())}


def _task_fingerprint(task: CampaignTask, cfg: EngineConfig,
                      chunk: int) -> str:
    h = hashlib.sha1()

    def upd(obj):
        h.update(json.dumps(obj, sort_keys=True, default=str).encode())

    upd({"scenario": list(SweepRunner._scenario_key(task.topo, task.sched)),
         "policy": _policy_token(task.policy),
         "policy_axis": list(task.policy_axis),
         "cc_params": {k: float(v)
                       for k, v in sorted((task.cc_params or {}).items())},
         "cfg": repr(_cfg_static(cfg)),
         "chunk": int(chunk), "n_lanes": int(task.n_lanes)})
    for label, d in (("params", task.stacked_params),
                     ("fabric", task.stacked_fabric),
                     ("fault", task.stacked_fault)):
        for k in sorted(d or {}):
            h.update(f"{label}.{k}".encode())
            h.update(np.ascontiguousarray(
                np.asarray(d[k], np.float32)).tobytes())
    fab = _as_fabric(task.fabric_params, cfg)
    flt = _as_fault(task.fault_spec)
    for f in FabricParams.FIELDS:
        h.update(np.ascontiguousarray(
            np.asarray(getattr(fab, f), np.float32)).tobytes())
    for f in FaultSpec.FIELDS:
        h.update(np.ascontiguousarray(
            np.asarray(getattr(flt, f), np.float32)).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# journal I/O (atomic tmp-file + rename, corrupt files log-and-rerun)
# ---------------------------------------------------------------------------

def _atomic_json(path: str, obj: dict) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1, default=str)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _save_chunk(path: str, arrays: dict, meta: dict) -> None:
    payload = {k: np.asarray(arrays[k]) for k in RESULT_KEYS}
    payload["__meta__"] = np.frombuffer(
        json.dumps(meta, default=str).encode(), np.uint8).copy()
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _load_chunk(path: str):
    """(arrays, meta) or None — a corrupt/truncated chunk (killed before
    the atomic-rename era, disk trouble) is warned about and re-run, not
    fatal."""
    if not os.path.exists(path):
        return None
    try:
        with np.load(path) as z:
            arrays = {k: np.asarray(z[k]) for k in RESULT_KEYS}
            meta = json.loads(bytes(z["__meta__"]).decode())
        return arrays, meta
    except Exception as e:
        warnings.warn(f"ignoring unreadable journal chunk {path} "
                      f"({type(e).__name__}: {e}); it will be re-run",
                      RuntimeWarning, stacklevel=2)
        return None


def _clean_tmp(journal: str) -> None:
    for fn in os.listdir(journal):
        if ".tmp." in fn:
            try:
                os.unlink(os.path.join(journal, fn))
            except OSError:
                pass


# ---------------------------------------------------------------------------
# chunk dispatch: the retry ladder's rungs
# ---------------------------------------------------------------------------

def _applicable_ladder(runner: SweepRunner, cfg: EngineConfig) -> tuple:
    rungs = ["half_chunk"]
    if resolve_step_impl(cfg) != "jnp":
        rungs.append("jnp_step")
    if runner.mesh is not None:
        rungs.append("no_mesh")
    rungs.append("serial")
    return tuple(rungs)


def _chunk_arrays(batch: BatchResults) -> dict:
    return {"completion_time": batch.completion_time,
            "t_finish": batch.t_finish,
            "pause_count": batch.pause_count,
            "delivered": batch.delivered,
            "soft_cost": batch.soft_cost,
            "finished": batch.finished,
            "diverged": batch.diverged,
            "deadlock_step": batch.deadlock_step,
            "storm_step": batch.storm_step,
            "extend_exhausted": batch.extend_exhausted}


def _normalized_lanes(task: CampaignTask, cfg: EngineConfig):
    """Replicate ``run_batch``'s lane normalization for the full task:
    (policy, full CC dict, stacked FabricParams, stacked FaultSpec)."""
    policy = _resolve(task.policy)
    B = task.n_lanes
    base_cc = dict(policy.params, **(task.cc_params or {}))
    sp = task.stacked_params or {}
    full = {k: np.asarray(sp.get(k, np.full(B, float(v))), np.float32)
            for k, v in base_cc.items()}
    cfg0 = dataclasses.replace(cfg, queue_stride=0)
    fab = _stack_fabric(_as_fabric(task.fabric_params, cfg0),
                        task.stacked_fabric, B)
    flt = _stack_fault(_as_fault(task.fault_spec), task.stacked_fault, B)
    return policy, full, fab, flt


def _serial_lanes(runner: SweepRunner, task: CampaignTask,
                  cfg: EngineConfig, idx: np.ndarray) -> dict:
    """Bottom rung: one engine run per lane.  Uses the fully-normalized
    per-lane param/fabric/fault sets (``Simulator.run`` takes the raw
    dict, so baked keys and the stacked-policy ``_which`` selector pass
    through unchanged)."""
    policy, full, fab, flt = _normalized_lanes(task, cfg)
    cfg = dataclasses.replace(cfg, queue_stride=0)
    rows = []
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for i in idx:
            cc_i = {k: np.float32(v[i]) for k, v in full.items()}
            fab_i = FabricParams(**{f: np.asarray(getattr(fab, f))[i]
                                    for f in FabricParams.FIELDS})
            flt_i = FaultSpec(**{f: np.asarray(getattr(flt, f))[i]
                                 for f in FaultSpec.FIELDS})
            rows.append(runner.run(task.topo, task.sched, policy,
                                   cc_params=cc_i, cfg=cfg,
                                   fabric_params=fab_i, fault_spec=flt_i))
    return {
        "completion_time": np.asarray([r.completion_time for r in rows],
                                      np.float32),
        "t_finish": np.stack([np.asarray(r.t_finish) for r in rows]),
        "pause_count": np.stack([np.asarray(r.pause_count) for r in rows]),
        "delivered": np.stack([np.asarray(r.delivered) for r in rows]),
        "soft_cost": np.asarray([r.soft_cost for r in rows], np.float32),
        "finished": np.asarray([r.finished for r in rows], bool),
        "diverged": np.asarray([r.diverged for r in rows], bool),
        "deadlock_step": np.asarray([r.deadlock_step for r in rows],
                                    np.int32),
        "storm_step": np.asarray([r.storm_step for r in rows], np.int32),
        "extend_exhausted": np.asarray([r.extend_exhausted for r in rows],
                                       bool),
    }


def _dispatch_chunk(runner: SweepRunner, task: CampaignTask,
                    cfg: EngineConfig, idx: np.ndarray,
                    demotions: tuple) -> dict:
    """Run lanes ``idx`` of ``task`` under the given cumulative demotion
    set and return the journal arrays."""
    if "serial" in demotions:
        return _serial_lanes(runner, task, cfg, idx)
    eff_cfg = cfg
    if "jnp_step" in demotions:
        eff_cfg = dataclasses.replace(eff_cfg, step_impl="jnp")
    sub = runner
    sub_chunk = None
    if "half_chunk" in demotions:
        sub_chunk = max(1, (len(idx) + 1) // 2)
    if "no_mesh" in demotions and runner.mesh is not None:
        sub = SweepRunner(cfg=runner.cfg, bucket=runner.bucket, mesh=None,
                          chunk_lanes=sub_chunk or runner.chunk_lanes,
                          dispatch_hook=runner.dispatch_hook)
    elif sub_chunk is not None:
        sub = SweepRunner(cfg=runner.cfg, bucket=runner.bucket,
                          mesh=runner.mesh, chunk_lanes=sub_chunk,
                          dispatch_hook=runner.dispatch_hook)
    sp, sf, sq = task._sliced(idx)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        batch = sub.run_batch(task.topo, task.sched, task.policy, sp,
                              stacked_fabric=sf,
                              fabric_params=task.fabric_params,
                              cc_params=task.cc_params, cfg=eff_cfg,
                              policy_axis=task.policy_axis,
                              stacked_fault=sq,
                              fault_spec=task.fault_spec)
    return _chunk_arrays(batch)


def _run_with_timeout(fn, timeout_s):
    """Watchdog: run ``fn`` on a worker thread and raise ``ChunkTimeout``
    if it outlives ``timeout_s``.  The hung dispatch thread cannot be
    killed — it is left daemonized and the campaign checkpoints and
    exits (the process is expected to terminate soon after)."""
    if not timeout_s:
        return fn()
    box: dict = {}
    done = threading.Event()

    def target():
        try:
            box["out"] = fn()
        except BaseException as e:          # noqa: BLE001 — re-raised below
            box["err"] = e
        finally:
            done.set()

    th = threading.Thread(target=target, daemon=True,
                          name="campaign-chunk-dispatch")
    th.start()
    done.wait(timeout_s)
    if not done.is_set():
        raise ChunkTimeout(f"chunk dispatch exceeded {timeout_s:g}s "
                           "watchdog; checkpointing and exiting")
    th.join()
    if "err" in box:
        raise box["err"]
    return box["out"]


# ---------------------------------------------------------------------------
# merge + result
# ---------------------------------------------------------------------------

def _fill_arrays(n: int, F: int, D: int) -> dict:
    """Journal-shaped placeholder rows for uncovered lanes: NaN where a
    measurement would be, inert flags elsewhere."""
    return {"completion_time": np.full(n, np.nan, np.float32),
            "t_finish": np.full((n, F), np.nan, np.float32),
            "pause_count": np.zeros((n, D), np.float32),
            "delivered": np.full((n, F), np.nan, np.float32),
            "soft_cost": np.full(n, np.nan, np.float32),
            "finished": np.zeros(n, bool),
            "diverged": np.zeros(n, bool),
            "deadlock_step": np.full(n, -1, np.int32),
            "storm_step": np.full(n, -1, np.int32),
            "extend_exhausted": np.zeros(n, bool)}


def _status_of(arrays: dict, i: int) -> LaneStatus:
    return classify_lane(bool(arrays["diverged"][i]),
                         bool(arrays["deadlock_step"][i] >= 0),
                         bool(arrays["finished"][i]))


def _merged_batch(task: CampaignTask, cfg: EngineConfig,
                  arrays: dict) -> BatchResults:
    policy, full, fab, flt = _normalized_lanes(task, cfg)
    faulty = is_faulty(flt)
    return BatchResults(
        policy=policy.name, params=full,
        fabric={k: np.asarray(getattr(fab, k))
                for k in FabricParams.FIELDS},
        completion_time=arrays["completion_time"],
        t_finish=arrays["t_finish"],
        pause_count=arrays["pause_count"],
        delivered=arrays["delivered"],
        soft_cost=arrays["soft_cost"],
        finished=arrays["finished"],
        policy_axis=tuple(task.policy_axis),
        fault=({k: np.asarray(getattr(flt, k)) for k in FaultSpec.FIELDS}
               if faulty else {}),
        diverged=arrays["diverged"],
        deadlock_step=arrays["deadlock_step"],
        storm_step=arrays["storm_step"],
        extend_exhausted=arrays["extend_exhausted"],
    )


@dataclasses.dataclass
class CampaignResult:
    """What ``run_campaign`` hands back: merged per-task ``BatchResults``
    plus the structured manifest (also on disk as ``manifest.json``)."""
    name: str
    out_dir: str
    status: str            # "complete" | "partial" | "deadline" | "chunk_timeout"
    results: dict          # task name -> BatchResults
    manifest: dict

    @property
    def ok(self) -> bool:
        return (self.status == "complete"
                and float(self.manifest.get("coverage", 0.0)) >= 1.0)


# ---------------------------------------------------------------------------
# the campaign driver
# ---------------------------------------------------------------------------

def run_campaign(tasks, name: str, out_dir: str = "experiments",
                 runner: SweepRunner | None = None,
                 cfg: EngineConfig | None = None,
                 chunk_lanes: int | None = None,
                 resume: bool = False, fresh: bool = False,
                 max_retries: int = 3, backoff_s: float = 0.5,
                 deadline_s: float | None = None,
                 chunk_timeout_s: float | None = None,
                 quarantine: bool = True,
                 quarantine_relax: float = 4.0,
                 quarantine_statuses=(LaneStatus.DIVERGED,
                                      LaneStatus.DEADLOCKED,
                                      LaneStatus.EXHAUSTED),
                 progress=None) -> CampaignResult:
    """Execute ``tasks`` with journaling, retries, quarantine, deadlines.

    ``resume=True`` replays completed chunks from the journal (after
    verifying the campaign fingerprint matches; mismatch raises
    ``CampaignFingerprintMismatch``).  ``resume=False`` on a non-empty
    journal refuses unless ``fresh=True`` wipes it first.  ``max_retries``
    caps retry attempts per chunk *beyond* the first (each retry takes one
    more rung down the demotion ladder and backs off exponentially from
    ``backoff_s``); a chunk that exhausts the ladder and budget is marked
    failed and the campaign continues (``status="partial"``, uncovered
    lanes NaN-filled and listed in the manifest).  ``deadline_s`` /
    ``chunk_timeout_s`` trigger checkpoint-and-exit with a partial
    manifest.  ``progress`` is an optional ``callable(str)``.
    """
    t_start = time.monotonic()
    say = progress or (lambda _msg: None)
    runner = runner or SweepRunner(cfg=cfg, chunk_lanes=chunk_lanes
                                   if chunk_lanes else "auto")
    base_cfg = cfg or runner.cfg

    tasks = list(tasks)
    safe_names = [_sanitize(t.name) for t in tasks]
    if len(set(safe_names)) != len(safe_names):
        raise CampaignError(f"duplicate task names: {sorted(safe_names)}")

    camp_dir = os.path.join(out_dir, _sanitize(name))
    journal = os.path.join(camp_dir, JOURNAL_DIR)
    os.makedirs(journal, exist_ok=True)
    _clean_tmp(journal)

    # -- fingerprint + resume gate ---------------------------------------
    plans = []
    for t, safe in zip(tasks, safe_names):
        tcfg = t.cfg or base_cfg
        B = t.n_lanes
        chunk = (min(B, max(int(chunk_lanes), 1)) if chunk_lanes
                 else runner._chunk_size(B))
        n_chunks = -(-B // chunk)
        plans.append({"task": t, "safe": safe, "cfg": tcfg, "B": B,
                      "chunk": chunk, "n_chunks": n_chunks,
                      "fingerprint": _task_fingerprint(t, tcfg, chunk)})
    fp = {"campaign": _sanitize(name), "jax": jax.__version__,
          "tasks": {p["safe"]: {"fingerprint": p["fingerprint"],
                                "n_lanes": p["B"], "chunk": p["chunk"],
                                "n_chunks": p["n_chunks"]}
                    for p in plans}}
    fp["fingerprint"] = hashlib.sha1(json.dumps(
        fp["tasks"], sort_keys=True).encode() +
        jax.__version__.encode()).hexdigest()

    fp_path = os.path.join(camp_dir, FINGERPRINT)
    have_chunks = any(f.endswith(".npz") for f in os.listdir(journal))
    if os.path.exists(fp_path) and have_chunks:
        try:
            with open(fp_path) as f:
                on_disk = json.load(f)
        except (OSError, ValueError):
            on_disk = None
        if resume:
            if on_disk is None or on_disk.get("fingerprint") != \
                    fp["fingerprint"]:
                raise CampaignFingerprintMismatch(
                    f"journal at {journal} was written by a different "
                    "campaign definition (tasks/grids/config/jax "
                    "changed); pass fresh=True to discard it")
        elif fresh:
            for fn in os.listdir(journal):
                os.unlink(os.path.join(journal, fn))
            for fn in (MANIFEST,):
                p = os.path.join(camp_dir, fn)
                if os.path.exists(p):
                    os.unlink(p)
        else:
            raise CampaignError(
                f"journal at {journal} is non-empty; pass resume=True to "
                "continue it or fresh=True to discard it")
    _atomic_json(fp_path, fp)

    manifest = {"campaign": fp["campaign"], "fingerprint": fp["fingerprint"],
                "jax": jax.__version__, "status": "running",
                "config": {"chunk_lanes": chunk_lanes,
                           "max_retries": max_retries,
                           "backoff_s": backoff_s,
                           "deadline_s": deadline_s,
                           "chunk_timeout_s": chunk_timeout_s,
                           "quarantine": quarantine,
                           "quarantine_relax": quarantine_relax,
                           "mesh_devices": runner.n_mesh_devices},
                "tasks": {}, "coverage": 0.0, "wall_s": 0.0}

    def checkpoint(status):
        manifest["status"] = status
        covered = total = 0
        for p in plans:
            ts = manifest["tasks"].get(p["safe"])
            total += p["B"]
            if ts:
                covered += round(ts["coverage"] * p["B"])
        manifest["coverage"] = covered / total if total else 0.0
        manifest["wall_s"] = round(time.monotonic() - t_start, 3)
        _atomic_json(os.path.join(camp_dir, MANIFEST), manifest)

    def past_deadline():
        return (deadline_s is not None
                and time.monotonic() - t_start > deadline_s)

    results: dict = {}
    exit_status: str | None = None
    any_failed = False

    for p in plans:
        task, safe, tcfg = p["task"], p["safe"], p["cfg"]
        B, chunk, n_chunks = p["B"], p["chunk"], p["n_chunks"]
        ladder = _applicable_ladder(runner, tcfg)
        level = 0                        # sticky demotion level for the task
        tstate = {"n_lanes": B, "chunk_lanes": chunk, "n_chunks": n_chunks,
                  "ladder": list(ladder), "chunks": [], "demotions": [],
                  "quarantine": None, "uncovered_lanes": [],
                  "coverage": 0.0, "lane_status": None}
        manifest["tasks"][safe] = tstate
        chunk_arrays: dict = {}

        for ci in range(n_chunks):
            lo, hi = ci * chunk, min((ci + 1) * chunk, B)
            cpath = os.path.join(journal, f"{safe}__c{ci:04d}.npz")
            loaded = _load_chunk(cpath)
            if loaded is not None and loaded[1].get("lo") == lo \
                    and loaded[1].get("hi") == hi:
                chunk_arrays[ci] = loaded[0]
                rec = dict(loaded[1], index=ci, status="replayed")
                tstate["chunks"].append(rec)
                continue
            if past_deadline():
                exit_status = "deadline"
                break
            attempts = []
            while True:
                demos = ladder[:level]
                t0 = time.perf_counter()
                try:
                    arrays = _run_with_timeout(
                        lambda d=demos: _dispatch_chunk(
                            runner, task, tcfg, np.arange(lo, hi), d),
                        chunk_timeout_s)
                except ChunkTimeout as e:
                    attempts.append({"demotions": list(demos),
                                     "error": str(e),
                                     "wall_s": round(
                                         time.perf_counter() - t0, 3)})
                    tstate["chunks"].append(
                        {"index": ci, "lo": lo, "hi": hi,
                         "status": "timeout", "attempts": attempts})
                    exit_status = "chunk_timeout"
                    break
                except Exception as e:   # the retry ladder's domain
                    wall = round(time.perf_counter() - t0, 3)
                    attempts.append({"demotions": list(demos),
                                     "error": f"{type(e).__name__}: {e}"[:300],
                                     "wall_s": wall})
                    if len(attempts) > max_retries:
                        tstate["chunks"].append(
                            {"index": ci, "lo": lo, "hi": hi,
                             "status": "failed", "attempts": attempts})
                        any_failed = True
                        say(f"{safe} chunk {ci}: FAILED after "
                            f"{len(attempts)} attempts")
                        break
                    if level < len(ladder):
                        level += 1
                        tstate["demotions"].append(
                            {"chunk": ci, "rung": ladder[level - 1],
                             "after_error": attempts[-1]["error"]})
                        say(f"{safe} chunk {ci}: demoting to "
                            f"{ladder[:level]} after "
                            f"{attempts[-1]['error']}")
                    if backoff_s:
                        time.sleep(backoff_s * 2 ** (len(attempts) - 1))
                else:
                    wall = round(time.perf_counter() - t0, 3)
                    meta = {"lo": lo, "hi": hi,
                            "attempts": len(attempts) + 1,
                            "demotions": list(demos), "wall_s": wall}
                    _save_chunk(cpath, arrays, meta)
                    chunk_arrays[ci] = {k: np.asarray(arrays[k])
                                        for k in RESULT_KEYS}
                    tstate["chunks"].append(
                        dict(meta, index=ci, status="done"))
                    break
            if exit_status:
                break

        # -- merge this task's journaled chunks ---------------------------
        if chunk_arrays:
            ref = next(iter(chunk_arrays.values()))
            F = ref["t_finish"].shape[1]
            D = ref["pause_count"].shape[1]
        else:
            sim = runner.simulator(task.topo, task.sched,
                                   _resolve(task.policy),
                                   dataclasses.replace(tcfg, queue_stride=0))
            F, D = sim.plan.n_flows, sim.plan.n_dev
        parts, covered = [], np.zeros(B, bool)
        for ci in range(n_chunks):
            lo, hi = ci * chunk, min((ci + 1) * chunk, B)
            got = chunk_arrays.get(ci)
            if got is None:
                parts.append(_fill_arrays(hi - lo, F, D))
            else:
                parts.append(got)
                covered[lo:hi] = True
        merged = {k: np.concatenate([pt[k] for pt in parts], axis=0)
                  for k in RESULT_KEYS}

        # -- lane quarantine ----------------------------------------------
        if quarantine and exit_status is None:
            qset = {LaneStatus(s) for s in quarantine_statuses}
            qlanes = [i for i in range(B) if covered[i]
                      and _status_of(merged, i) in qset]
            if qlanes:
                qpath = os.path.join(journal, f"{safe}__q.npz")
                qrec = {"lanes": [int(i) for i in qlanes],
                        "before": [str(_status_of(merged, i))
                                   for i in qlanes],
                        "relax": quarantine_relax,
                        "after": None, "patched": [], "error": None}
                qcfg = dataclasses.replace(
                    tcfg, max_steps=int(tcfg.max_steps * quarantine_relax))
                qloaded = _load_chunk(qpath)
                qarrays = None
                if qloaded is not None and \
                        qloaded[1].get("lanes") == qrec["lanes"]:
                    qarrays = qloaded[0]
                    qrec["status"] = "replayed"
                elif not past_deadline():
                    try:
                        qarrays = _run_with_timeout(
                            lambda: _dispatch_chunk(
                                runner, task, qcfg,
                                np.asarray(qlanes, np.int64), ()),
                            chunk_timeout_s)
                        _save_chunk(qpath, qarrays,
                                    {"lanes": qrec["lanes"],
                                     "relax": quarantine_relax})
                        qrec["status"] = "done"
                    except Exception as e:
                        qrec["error"] = f"{type(e).__name__}: {e}"[:300]
                        qrec["status"] = "failed"
                        say(f"{safe} quarantine retry failed: "
                            f"{qrec['error']}")
                else:
                    qrec["status"] = "skipped_deadline"
                if qarrays is not None:
                    after = []
                    for j, lane in enumerate(qlanes):
                        st = _status_of(qarrays, j)
                        after.append(str(st))
                        if st is LaneStatus.OK:   # only patch healed lanes
                            for k in RESULT_KEYS:
                                merged[k][lane] = qarrays[k][j]
                            qrec["patched"].append(int(lane))
                    qrec["after"] = after
                tstate["quarantine"] = qrec

        batch = _merged_batch(task, tcfg, merged)
        results[task.name] = batch
        tstate["uncovered_lanes"] = [int(i) for i in np.where(~covered)[0]]
        tstate["coverage"] = float(covered.mean()) if B else 1.0
        status_list = [str(s) if covered[i] else "uncovered"
                       for i, s in enumerate(batch.lane_status())]
        tstate["lane_status"] = {
            s: status_list.count(s) for s in dict.fromkeys(status_list)}
        checkpoint(exit_status or "running")
        say(f"{safe}: coverage {tstate['coverage']:.0%} "
            f"({tstate['lane_status']})")
        if exit_status:
            break

    if exit_status is None:
        exit_status = "partial" if any_failed or any(
            ts["coverage"] < 1.0 for ts in manifest["tasks"].values()) \
            else "complete"
    checkpoint(exit_status)
    return CampaignResult(name=fp["campaign"], out_dir=camp_dir,
                          status=exit_status, results=results,
                          manifest=manifest)


# ---------------------------------------------------------------------------
# the shared smoke campaign (CLI --smoke and the kill/resume tests)
# ---------------------------------------------------------------------------

def smoke_tasks(n_grid: int = 12) -> tuple[list, EngineConfig]:
    """A tiny two-task campaign (a dcqcn CC-param sweep and a lossy-RoCE
    fault sweep on a 4-GPU ring all-reduce) sized so ``chunk_lanes=4``
    yields several journaled chunks in seconds — shared by
    ``scripts/run_campaign.py --smoke`` and the crash/resume tests."""
    from repro.core.collectives import allreduce_1d
    from repro.core.topology import single_switch

    cfg = EngineConfig(dt=2e-6, max_steps=600, max_extends=1,
                       queue_stride=0)
    topo = single_switch(4)
    sched = allreduce_1d(topo, list(range(4)), 4e6)
    tasks = [
        CampaignTask(
            "dcqcn_rai", topo, sched, "dcqcn",
            stacked_params={"rai_frac": np.geomspace(
                0.005, 0.2, n_grid).astype(np.float32)}),
        CampaignTask(
            "hpcc_lossy", topo, sched, "hpcc",
            stacked_fault={"loss_rate": np.asarray(
                [0.0, 1e-5, 1e-4, 1e-3], np.float32),
                "pfc_on": np.zeros(4, np.float32)}),
    ]
    return tasks, cfg
