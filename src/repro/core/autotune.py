"""Differentiable CC parameter tuning (beyond-paper).

The paper: "DCQCN has many parameters that need to be tuned for better
performance ... tuning the congestion control hyperparameter before
running every deep learning workload is not a feasible solution."

Because our fluid network layer is pure JAX, the *whole simulation* is
differentiable w.r.t. the CC policy parameters.  We tune them by gradient
descent on a soft objective (integral of undelivered traffic fraction +
PFC pressure), replacing the paper's manual grid search.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.cc import Policy
from repro.core.engine import EngineConfig, Simulator


@dataclasses.dataclass
class TuneResult:
    params: dict
    history: list
    baseline_cost: float
    tuned_cost: float


def autotune(topo, sched, policy: Policy, tune_keys: list[str],
             steps: int = 12, lr: float = 0.15,
             cfg: EngineConfig | None = None) -> TuneResult:
    """Gradient-descent the selected (log-space) params of ``policy``."""
    cfg = cfg or EngineConfig(dt=2e-6, max_steps=2500, max_extends=0)
    sim = Simulator(topo, sched, policy, cfg)

    base = dict(policy.params)
    logp0 = {k: jnp.log(jnp.asarray(float(base[k]), jnp.float32)) for k in tune_keys}

    def cost_fn(logp):
        params = dict(base)
        for k, v in logp.items():
            params[k] = jnp.exp(v)
        return sim.soft_cost(params)

    vg = jax.jit(jax.value_and_grad(cost_fn))
    logp = logp0
    hist = []
    c0 = float(cost_fn(logp0))
    best, best_logp = c0, logp0
    for i in range(steps):
        c, g = vg(logp)
        c = float(c)
        hist.append({"step": i, "cost": c,
                     **{k: float(jnp.exp(v)) for k, v in logp.items()}})
        if c < best:
            best, best_logp = c, logp
        # normalized gradient step in log space
        gn = {k: jnp.clip(g[k], -10, 10) for k in g}
        logp = {k: logp[k] - lr * gn[k] for k in logp}
    tuned = {k: float(jnp.exp(v)) for k, v in best_logp.items()}
    return TuneResult(params=dict(base, **tuned), history=hist,
                      baseline_cost=c0, tuned_cost=best)
