"""Differentiable CC *and fabric* parameter tuning (beyond-paper).

The paper: "DCQCN has many parameters that need to be tuned for better
performance ... tuning the congestion control hyperparameter before
running every deep learning workload is not a feasible solution."

Because our fluid network layer is pure JAX, the *whole simulation* is
differentiable w.r.t. the CC policy parameters — and, since the scenario
refactor, w.r.t. the fabric's ECN/PFC knobs (``FabricParams``) too.  We
tune them by gradient descent on a soft objective (integral of undelivered
traffic fraction + PFC pressure), replacing the paper's manual grid
search.

Population-based tuning: with ``population > 1`` the search runs a whole
population of (log-space) parameter vectors through one ``vmap``-batched
``value_and_grad`` per step — a single compiled simulation evaluates every
member, so P-member tuning costs roughly one member's wall time, and the
spread of deterministic initial offsets makes the gradient descent robust
to the simulator's plateaus.  Member 0 always starts at the policy's
published defaults, so ``baseline_cost`` is comparable across runs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cc import Policy
from repro.core.engine import EngineConfig, FabricParams, Simulator, _as_fabric


@dataclasses.dataclass
class TuneResult:
    params: dict
    history: list
    baseline_cost: float
    tuned_cost: float
    fabric: FabricParams | None = None   # tuned fabric (when fabric_keys set)


def autotune(topo, sched, policy: Policy, tune_keys: list[str],
             steps: int = 12, lr: float = 0.15,
             cfg: EngineConfig | None = None,
             population: int = 1, spread: float = 0.4,
             fabric_params: FabricParams | None = None,
             fabric_keys: list[str] | None = None,
             cc_params: dict | None = None) -> TuneResult:
    """Gradient-descent the selected (log-space) params of ``policy``.

    ``population`` > 1 tunes that many jittered members in one vmapped
    simulation per step (population-based tuning); the best member wins.
    ``fabric_keys`` additionally tunes the named ``FabricParams`` fields
    (e.g. ``["kmin", "xoff"]``) through the same objective — the fabric is
    a traced input, so this costs no extra compiles.  ``cc_params``
    overrides the policy defaults for the *untuned* starting point (a
    ScenarioSpec's per-run overrides arrive here via ``autotune_spec``).
    """
    policy.check_tunable(tune_keys)
    if cc_params:
        policy.check_tunable(cc_params)
    fabric_keys = list(fabric_keys or [])
    FabricParams.check_fields(fabric_keys)
    cfg = cfg or EngineConfig(dt=2e-6, max_steps=2500, max_extends=0,
                              queue_stride=0)
    sim = Simulator(topo, sched, policy, cfg, fabric_params=fabric_params)
    cost_of_params = sim.soft_cost_fn()

    base = dict(policy.params, **(cc_params or {}))
    base_fab = _as_fabric(fabric_params, cfg)
    for k in fabric_keys:
        if np.asarray(getattr(base_fab, k)).ndim > 0:
            raise ValueError(
                f"fabric param {k!r} holds a per-link-class array; autotune "
                "tunes scalar fabric leaves only — tune a scalar base and "
                "apply with_class afterwards")
    all_keys = list(tune_keys) + [f"fabric.{k}" for k in fabric_keys]

    def cost_fn(logp):
        params = dict(base)
        fab_over = {}
        for k, v in logp.items():
            if k.startswith("fabric."):
                fab_over[k[len("fabric."):]] = jnp.exp(v)
            else:
                params[k] = jnp.exp(v)
        fab = base_fab.replace(**fab_over) if fab_over else base_fab
        return cost_of_params(params, fab)

    def start_val(k):
        if k.startswith("fabric."):
            return float(np.asarray(getattr(base_fab, k[len("fabric."):])))
        return float(base[k])

    P = max(int(population), 1)
    # deterministic log-space jitter; member 0 sits exactly at the defaults
    rng = np.random.default_rng(0)
    offs = np.zeros((P, len(all_keys)), np.float32)
    if P > 1:
        offs[1:] = rng.uniform(-spread, spread, size=(P - 1, len(all_keys)))
    logp = {k: jnp.asarray(np.log(start_val(k)) + offs[:, i], jnp.float32)
            for i, k in enumerate(all_keys)}

    vg = jax.jit(jax.vmap(jax.value_and_grad(cost_fn)))
    hist = []
    baseline = None
    best, best_logp = np.inf, None
    for i in range(steps):
        c, g = vg(logp)
        c = np.asarray(c)
        if i == 0:
            baseline = float(c[0])
        j = int(np.argmin(c))
        if c[j] < best:
            best = float(c[j])
            best_logp = {k: float(np.asarray(v)[j]) for k, v in logp.items()}
        hist.append({"step": i, "cost": float(c[j]),
                     "population_costs": [float(x) for x in c],
                     **{k: float(np.exp(np.asarray(v)[j]))
                        for k, v in logp.items()}})
        # normalized gradient step in log space, every member in parallel
        gn = {k: jnp.clip(g[k], -10, 10) for k in g}
        logp = {k: logp[k] - lr * gn[k] for k in logp}
    if best_logp is None:                       # steps == 0: evaluate once
        c = np.asarray(vg(logp)[0])
        j = int(np.argmin(c))
        baseline, best = float(c[0]), float(c[j])
        best_logp = {k: float(np.asarray(v)[j]) for k, v in logp.items()}
    tuned = {k: float(np.exp(v)) for k, v in best_logp.items()
             if not k.startswith("fabric.")}
    tuned_fab = None
    if fabric_keys:
        tuned_fab = base_fab.replace(
            **{k[len("fabric."):]: float(np.exp(v))
               for k, v in best_logp.items() if k.startswith("fabric.")})
    return TuneResult(params=dict(base, **tuned), history=hist,
                      baseline_cost=baseline, tuned_cost=best,
                      fabric=tuned_fab)


def autotune_spec(spec, tune_keys: list[str], **kw) -> TuneResult:
    """Declarative entry: tune a ``ScenarioSpec``'s policy (and optionally
    fabric) in place of the (topo, sched, policy) triple."""
    topo, sched, policy = spec.build()
    kw.setdefault("fabric_params", spec.fabric_params)
    kw.setdefault("cc_params", spec.cc_params)
    return autotune(topo, sched, policy, tune_keys, **kw)
