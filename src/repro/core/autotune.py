"""Differentiable CC *and fabric* parameter tuning (beyond-paper).

The paper: "DCQCN has many parameters that need to be tuned for better
performance ... tuning the congestion control hyperparameter before
running every deep learning workload is not a feasible solution."

Because our fluid network layer is pure JAX, the *whole simulation* is
differentiable w.r.t. the CC policy parameters — and, since the scenario
refactor, w.r.t. the fabric's ECN/PFC knobs (``FabricParams``) too.  We
tune them by gradient descent on a soft objective (integral of undelivered
traffic fraction), replacing the paper's manual grid search.

The search space is *declared*, not guessed: each tuned key's ``ParamSpec``
(``Policy.spec`` for CC params, ``engine.FABRIC_PARAM_SPECS`` for fabric
keys) decides how it moves —

* ``scale="log"``   -> descent in log-space (positive scale-free knobs);
* ``scale="linear"``-> descent in value space (bounded fractions);
* ``lo``/``hi``     -> tuned values are *projected* onto the declared
  bounds after every step (no more ``ecn_thresh`` drifting out of physical
  range under unbounded ``exp`` updates); each projection is recorded in
  ``TuneResult.history[i]["projected"]``;
* ``integer=True``  -> rejected with a clear error: gradient descent
  cannot tune count-valued params (``fast_rounds``, ``hai_after``,
  ``max_stage``) — sweep them via ``SweepRunner.grid`` /
  ``grid_from_spec`` instead.

Population-based tuning: with ``population > 1`` the search runs a whole
population of parameter vectors through one ``vmap``-batched
``value_and_grad`` per step — a single compiled simulation evaluates every
member, so P-member tuning costs roughly one member's wall time, and the
spread of deterministic initial offsets makes the gradient descent robust
to the simulator's plateaus.  Member 0 always starts at the policy's
published defaults, so ``baseline_cost`` is comparable across runs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cc import ParamSpec, Policy
from repro.core.engine import (FABRIC_PARAM_SPECS, EngineConfig,
                               FabricParams, Simulator, _as_fabric)


@dataclasses.dataclass
class TuneResult:
    params: dict
    history: list
    baseline_cost: float
    tuned_cost: float
    fabric: FabricParams | None = None   # tuned fabric (when fabric_keys set)


_FABRIC_NS = "fabric."


def _tune_spec(policy: Policy, key: str) -> ParamSpec:
    """ParamSpec of one tuned key (CC param or ``fabric.<field>``)."""
    if key.startswith(_FABRIC_NS):
        return FABRIC_PARAM_SPECS[key[len(_FABRIC_NS):]]
    return policy.param_spec(key)


def _check_tunable_by_gradient(policy: Policy, keys) -> None:
    ints = [k for k in keys if _tune_spec(policy, k).integer]
    if ints:
        raise ValueError(
            f"params {sorted(ints)} are integer-valued; gradient autotune "
            "cannot tune them as continuous floats — sweep them instead "
            "(SweepRunner.grid / grid_from_spec)")


def autotune(topo, sched, policy: Policy, tune_keys: list[str],
             steps: int = 12, lr: float = 0.15,
             cfg: EngineConfig | None = None,
             population: int = 1, spread: float = 0.4,
             fabric_params: FabricParams | None = None,
             fabric_keys: list[str] | None = None,
             cc_params: dict | None = None) -> TuneResult:
    """Gradient-descent the selected params of ``policy`` along their
    declared ``ParamSpec`` scales, projecting onto declared bounds.

    ``population`` > 1 tunes that many jittered members in one vmapped
    simulation per step (population-based tuning); the best member wins.
    ``fabric_keys`` additionally tunes the named ``FabricParams`` fields
    (e.g. ``["kmin", "xoff"]``) through the same objective — the fabric is
    a traced input, so this costs no extra compiles.  ``cc_params``
    overrides the policy defaults for the *untuned* starting point (a
    ScenarioSpec's per-run overrides arrive here via ``autotune_spec``).
    """
    policy.check_tunable(tune_keys)
    if cc_params:
        policy.check_tunable(cc_params)
    fabric_keys = list(fabric_keys or [])
    FabricParams.check_fields(fabric_keys)
    all_keys = list(tune_keys) + [_FABRIC_NS + k for k in fabric_keys]
    _check_tunable_by_gradient(policy, all_keys)
    specs = {k: _tune_spec(policy, k) for k in all_keys}
    cfg = cfg or EngineConfig(dt=2e-6, max_steps=2500, max_extends=0,
                              queue_stride=0)
    sim = Simulator(topo, sched, policy, cfg, fabric_params=fabric_params)
    cost_of_params = sim.soft_cost_fn()

    base = dict(policy.params, **(cc_params or {}))
    base_fab = _as_fabric(fabric_params, cfg)
    for k in fabric_keys:
        if np.asarray(getattr(base_fab, k)).ndim > 0:
            raise ValueError(
                f"fabric param {k!r} holds a per-link-class array; autotune "
                "tunes scalar fabric leaves only — tune a scalar base and "
                "apply with_class afterwards")

    # z-space: log for scale="log" keys, identity for linear ones
    def decode(k, z):
        return jnp.exp(z) if specs[k].scale == "log" else z

    def encode(k, v):
        return np.log(v) if specs[k].scale == "log" else float(v)

    def cost_fn(zp):
        params = dict(base)
        fab_over = {}
        for k, z in zp.items():
            v = decode(k, z)
            if k.startswith(_FABRIC_NS):
                fab_over[k[len(_FABRIC_NS):]] = v
            else:
                params[k] = v
        fab = base_fab.replace(**fab_over) if fab_over else base_fab
        return cost_of_params(params, fab)

    def start_val(k):
        if k.startswith(_FABRIC_NS):
            return float(np.asarray(getattr(base_fab, k[len(_FABRIC_NS):])))
        return float(base[k])

    def project(zp):
        """Clip every member onto the declared bounds; -> (zp, clamped
        key list).  Projection happens in value space, so log- and
        linear-scale keys share one code path."""
        out, clamped = {}, []
        for k, z in zp.items():
            v = np.asarray(decode(k, jnp.asarray(z)))
            vc = np.clip(v, specs[k].lo if specs[k].lo is not None else -np.inf,
                         specs[k].hi if specs[k].hi is not None else np.inf)
            if not np.array_equal(v, vc):
                clamped.append(k)
            out[k] = jnp.asarray([encode(k, x) for x in vc], jnp.float32)
        return out, clamped

    P = max(int(population), 1)
    # deterministic z-space jitter; member 0 sits exactly at the defaults
    rng = np.random.default_rng(0)
    offs = np.zeros((P, len(all_keys)), np.float32)
    if P > 1:
        offs[1:] = rng.uniform(-spread, spread, size=(P - 1, len(all_keys)))
    zp = {}
    for i, k in enumerate(all_keys):
        z0 = encode(k, start_val(k))
        # linear-scale offsets move relative to the param's range
        span = ((specs[k].hi - specs[k].lo)
                if specs[k].scale == "linear" and specs[k].bounded else 1.0)
        zp[k] = jnp.asarray(z0 + offs[:, i] * span, jnp.float32)
    zp, _ = project(zp)           # initial population inside bounds

    vg = jax.jit(jax.vmap(jax.value_and_grad(cost_fn)))
    hist = []
    baseline = None
    best, best_z = np.inf, None

    def snapshot(i, c, projected, bad):
        j = int(np.argmin(c))
        hist.append({"step": i, "cost": float(c[j]),
                     "population_costs": [float(x) for x in c],
                     "projected": sorted(projected),
                     "nonfinite_members": [int(m) for m in bad],
                     **{k: float(np.asarray(decode(k, jnp.asarray(v)))[j])
                        for k, v in zp.items()}})
        return j

    projected_now: list = []
    for i in range(steps):
        c, g = vg(zp)
        c = np.asarray(c)
        # non-finite guard: a NaN/inf cost or gradient (diverged lane,
        # pathological params) must not corrupt the population step —
        # freeze the offending member this step, never select it as best
        m_ok = np.isfinite(c)
        for k in g:
            m_ok &= np.all(np.isfinite(np.asarray(g[k]))
                           .reshape(P, -1), axis=1)
        bad = np.flatnonzero(~m_ok)
        c = np.where(m_ok, c, np.inf)
        if i == 0:
            baseline = float(c[0])
        j = snapshot(i, c, projected_now, bad)
        if c[j] < best:
            best = float(c[j])
            best_z = {k: float(np.asarray(v)[j]) for k, v in zp.items()}
        # clipped-gradient step, every member in parallel, then projection;
        # non-finite members take a zero step (their params stay put)
        ok = jnp.asarray(m_ok)
        gn = {k: jnp.where(ok, jnp.clip(g[k], -10, 10), 0.0) for k in g}
        zp = {k: zp[k] - lr * gn[k] for k in zp}
        zp, projected_now = project(zp)
    if best_z is None:                       # steps == 0: evaluate once
        c = np.asarray(vg(zp)[0])
        bad = np.flatnonzero(~np.isfinite(c))
        c = np.where(np.isfinite(c), c, np.inf)
        j = snapshot(0, c, [], bad)
        baseline, best = float(c[0]), float(c[j])
        best_z = {k: float(np.asarray(v)[j]) for k, v in zp.items()}

    def best_val(k):
        return float(np.asarray(decode(k, jnp.asarray(best_z[k]))))

    tuned = {k: best_val(k) for k in best_z if not k.startswith(_FABRIC_NS)}
    tuned_fab = None
    if fabric_keys:
        tuned_fab = base_fab.replace(
            **{k[len(_FABRIC_NS):]: best_val(k)
               for k in best_z if k.startswith(_FABRIC_NS)})
    return TuneResult(params=dict(base, **tuned), history=hist,
                      baseline_cost=baseline, tuned_cost=best,
                      fabric=tuned_fab)


def autotune_spec(spec, tune_keys: list[str], **kw) -> TuneResult:
    """Declarative entry: tune a ``ScenarioSpec``'s policy (and optionally
    fabric) in place of the (topo, sched, policy) triple."""
    topo, sched, policy = spec.build()
    kw.setdefault("fabric_params", spec.fabric_params)
    kw.setdefault("cc_params", spec.cc_params)
    return autotune(topo, sched, policy, tune_keys, **kw)
