"""Network topologies: single-switch star and the paper's two-level CLOS
(Fig 2: 8 GPUs + NVSwitch scale-up per node, 2 nodes/rack, dedicated NIC
per GPU to the ToR, full-bisection spine layer).

Everything is flat numpy arrays over *directed links*; devices exist only
as PFC domains and metric groups.  Table I parameters are the defaults.
"""
from __future__ import annotations

import dataclasses

import numpy as np

GB = 1024 ** 3
MB = 1024 ** 2
KB = 1024.0

# Table I defaults
NIC_BW = 200e9 / 8            # 200 Gbps -> bytes/s
NIC_LAT = 500e-9
NVLINK_BW = 200 * 1e9         # 200 GBps (total, scale-up)
NVLINK_LAT = 25e-9
SWITCH_BUF = 32 * MB

# Canonical fabric-link classes.  Every directed link belongs to exactly
# one class; ``FabricParams`` (engine layer) may carry per-class arrays of
# ECN/PFC knobs indexed by these ids, so tuning e.g. spine-downlink ECN
# separately from ToR downlinks is one array entry, not a new topology.
LINK_CLASSES = ("nvlink", "host_nic", "tor_down", "tor_up", "spine_down")
N_LINK_CLASSES = len(LINK_CLASSES)
LINK_CLASS_ID = {n: i for i, n in enumerate(LINK_CLASSES)}


@dataclasses.dataclass
class Topology:
    name: str
    n_devices: int
    # per directed link
    cap: np.ndarray            # bytes/s
    lat: np.ndarray            # s
    src_dev: np.ndarray        # device owning the egress queue
    dst_dev: np.ndarray        # device whose ingress port this link feeds
    ecn_on: np.ndarray         # bool: switch egress queues mark ECN
    fabric: np.ndarray         # bool: RoCE fabric link (PFC-capable port)
    link_class: np.ndarray     # int32 index into LINK_CLASSES
    # devices
    dev_is_switch: np.ndarray  # bool (PFC domain + metric group)
    dev_buf: np.ndarray        # bytes (PFC threshold base)
    dev_name: list
    # host routing helpers
    n_gpus: int
    up_link: np.ndarray        # gpu -> host->first-switch link id
    meta: dict

    @property
    def n_links(self) -> int:
        return len(self.cap)

    def gpu_dev(self, g: int) -> int:
        return g


class _Builder:
    def __init__(self, name):
        self.name = name
        self.cap, self.lat, self.src, self.dst, self.ecn = [], [], [], [], []
        self.fabric = []
        self.link_class = []
        self.dev_is_switch, self.dev_buf, self.dev_name = [], [], []

    def add_dev(self, name, is_switch, buf=SWITCH_BUF) -> int:
        self.dev_name.append(name)
        self.dev_is_switch.append(is_switch)
        self.dev_buf.append(buf if is_switch else 1e18)
        return len(self.dev_name) - 1

    def add_link(self, u, v, cap, lat, ecn, fabric=True,
                 cls="host_nic") -> int:
        self.cap.append(cap)
        self.lat.append(lat)
        self.src.append(u)
        self.dst.append(v)
        self.ecn.append(ecn)
        self.fabric.append(fabric)
        self.link_class.append(LINK_CLASS_ID[cls])
        return len(self.cap) - 1

    def build(self, n_gpus, up_link, meta) -> Topology:
        return Topology(
            name=self.name,
            n_devices=len(self.dev_name),
            cap=np.asarray(self.cap, np.float64),
            lat=np.asarray(self.lat, np.float64),
            src_dev=np.asarray(self.src, np.int32),
            dst_dev=np.asarray(self.dst, np.int32),
            ecn_on=np.asarray(self.ecn, bool),
            fabric=np.asarray(self.fabric, bool),
            link_class=np.asarray(self.link_class, np.int32),
            dev_is_switch=np.asarray(self.dev_is_switch, bool),
            dev_buf=np.asarray(self.dev_buf, np.float64),
            dev_name=self.dev_name,
            n_gpus=n_gpus,
            up_link=np.asarray(up_link, np.int32),
            meta=meta,
        )


def single_switch(n_gpus: int = 8, bw: float = NIC_BW, lat: float = NIC_LAT,
                  buf: float = SWITCH_BUF) -> Topology:
    """n GPUs on one switch (the paper's incast / §IV-B microbenchmarks)."""
    b = _Builder(f"single_switch_{n_gpus}")
    for g in range(n_gpus):
        b.add_dev(f"gpu{g}", False)
    sw = b.add_dev("sw0", True, buf)
    up, down = [], []
    for g in range(n_gpus):
        up.append(b.add_link(g, sw, bw, lat, ecn=False))   # host NIC egress
    for g in range(n_gpus):
        down.append(b.add_link(sw, g, bw, lat, ecn=True,
                               cls="tor_down"))            # switch egress
    meta = {"down_link": np.asarray(down, np.int32), "kind": "single",
            "switches": [sw]}
    return b.build(n_gpus, up, meta)


def clos(n_racks: int = 8, nodes_per_rack: int = 2, gpus_per_node: int = 8,
         n_spines: int = 8, nic_bw: float = NIC_BW, nic_lat: float = NIC_LAT,
         nv_bw: float = NVLINK_BW, nv_lat: float = NVLINK_LAT,
         buf: float = SWITCH_BUF) -> Topology:
    """The paper's two-level CLOS (Fig 2).  Defaults = 128 GPUs / 8 racks."""
    n_nodes = n_racks * nodes_per_rack
    n_gpus = n_nodes * gpus_per_node
    b = _Builder(f"clos_{n_gpus}")
    for g in range(n_gpus):
        b.add_dev(f"gpu{g}", False)
    nvsw = [b.add_dev(f"nvsw{n}", True, 16 * SWITCH_BUF) for n in range(n_nodes)]
    tors = [b.add_dev(f"tor{r}", True, buf) for r in range(n_racks)]
    spines = [b.add_dev(f"spine{s}", True, buf) for s in range(n_spines)]

    up = np.zeros(n_gpus, np.int32)
    nv_up = np.zeros(n_gpus, np.int32)
    nv_down = np.zeros(n_gpus, np.int32)
    tor_down = np.zeros(n_gpus, np.int32)
    for g in range(n_gpus):
        node = g // gpus_per_node
        rack = node // nodes_per_rack
        # scale-up (proprietary lossless fabric: credit-based, not PFC)
        nv_up[g] = b.add_link(g, nvsw[node], nv_bw, nv_lat, ecn=False,
                              fabric=False, cls="nvlink")
        nv_down[g] = b.add_link(nvsw[node], g, nv_bw, nv_lat, ecn=False,
                                fabric=False, cls="nvlink")
        # scale-out
        up[g] = b.add_link(g, tors[rack], nic_bw, nic_lat, ecn=False)
        tor_down[g] = b.add_link(tors[rack], g, nic_bw, nic_lat, ecn=True,
                                 cls="tor_down")
    tor_up = np.zeros((n_racks, n_spines), np.int32)
    spine_down = np.zeros((n_spines, n_racks), np.int32)
    for r in range(n_racks):
        for s in range(n_spines):
            tor_up[r, s] = b.add_link(tors[r], spines[s], nic_bw, nic_lat,
                                      ecn=True, cls="tor_up")
            spine_down[s, r] = b.add_link(spines[s], tors[r], nic_bw, nic_lat,
                                          ecn=True, cls="spine_down")

    meta = {
        "kind": "clos",
        "gpus_per_node": gpus_per_node,
        "nodes_per_rack": nodes_per_rack,
        "n_racks": n_racks,
        "n_spines": n_spines,
        "nv_up": nv_up, "nv_down": nv_down,
        "tor_down": tor_down, "tor_up": tor_up, "spine_down": spine_down,
        "tor_devs": np.asarray(tors, np.int32),
        "spine_devs": np.asarray(spines, np.int32),
        "switches": tors + spines,
    }
    return b.build(n_gpus, up, meta)


MAXHOP = 4


def route(topo: Topology, src: int, dst: int, ecmp_key: int) -> list[int]:
    """Directed link path src GPU -> dst GPU."""
    m = topo.meta
    if m["kind"] == "single":
        return [int(topo.up_link[src]), int(m["down_link"][dst])]
    gpn = m["gpus_per_node"]
    npr = m["nodes_per_rack"]
    s_node, d_node = src // gpn, dst // gpn
    s_rack, d_rack = s_node // npr, d_node // npr
    if s_node == d_node:
        return [int(m["nv_up"][src]), int(m["nv_down"][dst])]
    if s_rack == d_rack:
        return [int(topo.up_link[src]), int(m["tor_down"][dst])]
    spine = _ecmp_hash(ecmp_key) % m["n_spines"]
    return [int(topo.up_link[src]), int(m["tor_up"][s_rack, spine]),
            int(m["spine_down"][spine, d_rack]), int(m["tor_down"][dst])]


def _ecmp_hash(x: int) -> int:
    # deterministic avalanche mix (splitmix-ish) — per-flow ECMP
    x = (x ^ 61) ^ (x >> 16)
    x = (x + (x << 3)) & 0xFFFFFFFF
    x = x ^ (x >> 4)
    x = (x * 0x27D4EB2D) & 0xFFFFFFFF
    return (x ^ (x >> 15)) & 0x7FFFFFFF
