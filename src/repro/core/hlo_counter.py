"""Trip-count-aware structural profiler over compiled HLO text.

XLA's HloCostAnalysis counts `while` (scan) bodies ONCE, so scanned-layer /
grad-accumulation programs under-report FLOPs, bytes and collective traffic
by their trip counts.  This module rebuilds the call graph from the HLO
text (fusion / call / while / conditional), reads each while's trip count
(XLA's ``known_trip_count`` backend config), and aggregates bottom-up with
trip multiplication:

* ``flops``       — dot FLOPs: 2 * prod(output_dims) * contracted_size.
                    Exact for matmuls (validated vs analytic counts);
                    elementwise FLOPs deliberately ignored (MXU dominates).
* ``coll``        — collective bytes by kind (operand bytes of all-reduce /
                    all-gather / reduce-scatter / all-to-all / c-permute).
* ``bytes``       — per-touch upper bound: every non-free op charged
                    operands+output (what a non-fusing backend would move).
* ``bytes_floor`` — write-once floor: every materialized intermediate
                    charged once (its output), computation parameters
                    charged once per execution with *slice discounts*
                    (a stacked weight array consumed only through
                    dynamic-slice — directly or transitively through a
                    fusion — is charged at slice size: per-layer weight
                    reads inside a scan, not the whole stack).  Reads of
                    already-materialized intermediates are free (perfect
                    fusion).  True traffic lies between floor and upper.

Validated in tests/test_hlo_tools.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_PARAM_RE = re.compile(r"=\s*[a-z0-9(][^=]*?parameter\((\d+)\)")
_OP_KIND_RE = re.compile(
    r"=\s*(?:\([^=]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*([a-z0-9\-]+)\(")
_COMMENT_RE = re.compile(r"/\*.*?\*/")

_COLLS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")
_FREE_OPS = {"get-tuple-element", "tuple", "parameter", "constant", "bitcast",
             "bitcast-convert", "after-all", "partition-id", "replica-id",
             "iota"}
_SLICE_OPS = {"dynamic-slice", "slice", "gather"}
# ops whose output is necessarily materialized to HBM even under fusion
_MATERIALIZE = {"dot", "convolution", "sort", "copy", "custom-call",
                "rng", "rng-bit-generator", "cholesky", "triangular-solve",
                "select-and-scatter", "reduce-window",
                *_SLICE_OPS, *_COLLS}


def _shape_list(text: str):
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        out.append((dt, n))
    return out


def _nbytes(shapes) -> float:
    return float(sum(n * _DTYPE_BYTES[dt] for dt, n in shapes))


@dataclasses.dataclass
class Comp:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_floor: float = 0.0
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    children: list = dataclasses.field(default_factory=list)  # (name, kind, trip|None)
    max_const: int = 0
    param_charge: dict = dataclasses.field(default_factory=dict)  # idx -> bytes


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for raw in text.splitlines():
        if raw and raw[0] not in " \t}":
            m = re.match(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(", raw)
            if m and raw.rstrip().endswith("{"):
                cur = ("ENTRY::" if raw.startswith("ENTRY") else "") + m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if raw.strip() == "}":
                cur = None
            else:
                comps[cur].append(_COMMENT_RE.sub("", raw))
    return comps


def parse(text: str) -> dict[str, Comp]:
    raw_comps = _split_computations(text)
    out: dict[str, Comp] = {}

    for cname, lines in raw_comps.items():  # callees precede callers in HLO
        c = Comp()
        shapes_of: dict[str, list] = {}
        param_names: dict[str, int] = {}
        op_rows = []  # (name, kind, opnd_names, out_bytes, line)

        for ln in lines:
            nm = _NAME_RE.match(ln)
            if not nm:
                cm = _CONST_RE.search(ln)
                if cm:
                    c.max_const = max(c.max_const, int(cm.group(1)))
                continue
            name = nm.group(1)
            head = ln.split("=", 1)[1]
            i = head.find("(")
            shapes_of[name] = _shape_list(head[:i] if i > 0 else head)
            pm = _PARAM_RE.search(ln)
            km = _OP_KIND_RE.search(ln)
            kind = km.group(1).replace("-start", "") if km else None
            if pm and kind == "parameter":
                param_names[name] = int(pm.group(1))
            cm = _CONST_RE.search(ln)
            if cm:
                c.max_const = max(c.max_const, int(cm.group(1)))
            if kind is None:
                continue
            args_txt = ln.split("(", 1)[1].split("), ")[0]
            opnds = _OPND_RE.findall(args_txt)
            op_rows.append((name, kind, opnds, _nbytes(shapes_of[name]), ln))

        # ---- param consumer analysis (slice-transitive through fusions) ----
        slice_reads = {n: 0.0 for n in param_names}
        full_read = {n: False for n in param_names}
        for name, kind, opnds, out_b, ln in op_rows:
            for pos, o in enumerate(opnds):
                if o not in param_names:
                    continue
                if kind in _SLICE_OPS:
                    slice_reads[o] += out_b
                elif kind == "fusion":
                    cal = _CALL_RE.search(ln)
                    callee = out.get(cal.group(1)) if cal else None
                    real_pos = len([x for x in opnds[:pos] if x in shapes_of])
                    if callee is not None and real_pos in callee.param_charge:
                        slice_reads[o] += callee.param_charge[real_pos]
                    else:
                        full_read[o] = True
                elif kind in ("get-tuple-element", "tuple", "bitcast", "parameter"):
                    continue
                else:
                    full_read[o] = True
        for n, idx in param_names.items():
            full = _nbytes(shapes_of.get(n, []))
            c.param_charge[idx] = full if full_read[n] else min(slice_reads[n], full)
        # execution charge for reading this computation's inputs once
        c.bytes_floor += sum(c.param_charge.values())

        # ---- per-op charges -------------------------------------------------
        for name, kind, opnds, out_b, ln in op_rows:
            known = [o for o in opnds if o in shapes_of]
            opnd_b = sum(_nbytes(shapes_of[o]) for o in known)

            if kind == "dot":
                mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
                lhs_dims = _dims_of(lines, known[0]) if known else None
                if mm and lhs_dims is not None:
                    csize = 1
                    for ci in (int(x) for x in mm.group(1).split(",") if x.strip()):
                        if ci < len(lhs_dims):
                            csize *= lhs_dims[ci]
                    nout = sum(n for _, n in shapes_of.get(name, []))
                    c.flops += 2.0 * nout * csize
            if kind in _COLLS:
                c.coll[kind] += opnd_b

            # call edges
            if kind == "while":
                b = _CALL_RE.search(ln)
                tm = _TRIP_RE.search(ln)
                cd = _COND_RE.search(ln)
                trip = int(tm.group(1)) if tm else (cd.group(1) if cd else None)
                if b:
                    c.children.append((b.group(1), "while", trip))
            elif kind == "fusion":
                b = _CALL_RE.search(ln)
                if b:
                    c.children.append((b.group(1), "fusion", None))
            elif kind in ("call", "custom-call", "async-start"):
                b = _CALL_RE.search(ln)
                if b:
                    c.children.append((b.group(1), "call", None))
            elif kind == "conditional":
                bm = _BRANCH_RE.search(ln)
                if bm:
                    for br in bm.group(1).split(","):
                        c.children.append((br.strip().lstrip("%"), "call", None))

            # byte charges
            if kind in _FREE_OPS or kind == "while":
                continue
            if kind in _SLICE_OPS:
                c.bytes += 2.0 * out_b
            elif kind in ("dynamic-update-slice", "scatter"):
                upd = _nbytes(shapes_of[known[-1]]) if known else out_b
                c.bytes += 3.0 * upd
                c.bytes_floor += 3.0 * upd
            else:
                c.bytes += opnd_b + out_b
            if kind in _MATERIALIZE or kind == "fusion":
                c.bytes_floor += out_b
        out[cname] = c
    return out


def _dims_of(lines, name):
    pat = re.compile(r"%" + re.escape(name) + r"\s*=\s*[a-z0-9]+\[([0-9,]*)\]")
    for ln in lines:
        m = pat.search(ln)
        if m:
            return [int(d) for d in m.group(1).split(",") if d.strip()]
    return None


@dataclasses.dataclass
class Totals:
    flops: float
    bytes: float
    coll: dict
    bytes_floor: float = 0.0


def totals(hlo_text: str) -> Totals:
    comps = parse(hlo_text)
    alias = {n.split("::")[-1]: n for n in comps}
    entry = next((n for n in comps if n.startswith("ENTRY::")), None) or next(iter(comps))
    memo: dict[str, tuple] = {}

    def trip(t) -> int:
        if t is None:
            return 1
        if isinstance(t, int):
            return max(t, 1)
        c = comps.get(alias.get(t, t))
        return max(c.max_const, 1) if c else 1

    def rec(name: str, depth=0):
        full = alias.get(name, name)
        if full in memo:
            return memo[full]
        if full not in comps or depth > 128:
            return (0.0, 0.0, 0.0, {})
        memo[full] = (0.0, 0.0, 0.0, {})  # cycle guard
        t = comps[full]
        f, b, bf = t.flops, t.bytes, t.bytes_floor
        coll = dict(t.coll)
        for child, kind, cond in t.children:
            cf, cb, cbf, cc = rec(child, depth + 1)
            mult = trip(cond) if kind == "while" else 1
            f += cf * mult
            if kind != "fusion":  # fusion internals: interface-only
                b += cb * mult
                bf += cbf * mult
            for k, v in cc.items():
                coll[k] = coll.get(k, 0.0) + v * mult
        memo[full] = (f, b, bf, coll)
        return memo[full]

    f, b, bf, coll = rec(entry)
    coll["total"] = sum(v for k, v in coll.items() if k != "total")
    return Totals(flops=f, bytes=b, coll=coll, bytes_floor=bf)
