"""System layer: collective algorithms -> dependency-tagged flow schedules.

Mirrors ASTRA-Sim's system layer: each collective is decomposed into
send/recv *messages* (flows); hierarchical algorithms chain stages through
dependency groups; each collective is split into ``n_chunks`` equal chunks
processed in a pipeline (paper §III-D: 4 chunks).

A Schedule is plain numpy; the engine consumes it as static arrays.

All-reduce algorithms are registered in ``COLLECTIVES`` (the paper's
1D/2D/ring/a2a axis), so scenario specs and sweeps can enumerate them by
name: ``get_collective("ring")(topo, gpus, bytes)``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.topology import MAXHOP, Topology, route


# ---------------------------------------------------------------------------
# collective-algorithm registry (the paper's workload axis)
# ---------------------------------------------------------------------------

COLLECTIVES: dict[str, Callable] = {}


def register_collective(name: str, *aliases: str):
    """Register ``fn(topo, gpus, total_bytes, n_chunks=4) -> Schedule``."""
    def deco(fn):
        for n in (name,) + aliases:
            if n in COLLECTIVES:
                raise ValueError(f"collective {n!r} already registered")
            COLLECTIVES[n] = fn
        return fn
    return deco


def get_collective(name: str) -> Callable:
    try:
        return COLLECTIVES[name]
    except KeyError:
        raise KeyError(f"unknown collective {name!r}; registered: "
                       f"{sorted(COLLECTIVES)}") from None


@dataclasses.dataclass
class Schedule:
    """Flat flow schedule.  All sizes in bytes; times in seconds."""
    path: np.ndarray          # (F, MAXHOP) int32 link ids, -1 pad
    n_hops: np.ndarray        # (F,)
    size: np.ndarray          # (F,) bytes
    group: np.ndarray         # (F,) completion-group id
    dep: np.ndarray           # (F,) dep group id or -1
    delay: np.ndarray         # (F,) start delay after dep completion (s)
    n_groups: int
    group_names: list

    @property
    def n_flows(self) -> int:
        return len(self.size)

    def total_bytes(self) -> float:
        return float(self.size.sum())


class ScheduleBuilder:
    def __init__(self, topo: Topology):
        self.topo = topo
        self.rows: list = []          # (path, size, group, dep, delay)
        self.group_names: list = []

    def new_group(self, name: str) -> int:
        self.group_names.append(name)
        return len(self.group_names) - 1

    def add_flow(self, src: int, dst: int, size: float, group: int,
                 dep: int = -1, delay: float = 0.0, ecmp_salt: int = 0):
        key = (src * 131071 + dst * 8191 + ecmp_salt * 524287 + group) & 0x7FFFFFFF
        p = route(self.topo, src, dst, key)
        self.rows.append((p, size, group, dep, delay))

    def add_marker(self, group: int, dep: int = -1, delay: float = 0.0):
        """Zero-byte flow: pure time/dependency node (compute segments)."""
        self.rows.append(([-1], 0.0, group, dep, delay))

    def build(self) -> Schedule:
        F = len(self.rows)
        path = np.full((F, MAXHOP), -1, np.int32)
        n_hops = np.zeros(F, np.int32)
        size = np.zeros(F, np.float64)
        group = np.zeros(F, np.int32)
        dep = np.full(F, -1, np.int32)
        delay = np.zeros(F, np.float64)
        for i, (p, s, g, d, dl) in enumerate(self.rows):
            if p != [-1]:
                path[i, :len(p)] = p
                n_hops[i] = len(p)
            size[i] = s
            group[i] = g
            dep[i] = d
            delay[i] = dl
        # A flow may only depend on a strictly earlier group (-1 = none).
        # A dep on the flow's own group or a forward reference would stall
        # the simulation silently until max_steps; fail loudly instead.
        bad = np.nonzero(dep >= group)[0]
        if bad.size:
            f = int(bad[0])
            g, d = int(group[f]), int(dep[f])

            def gname(i):
                return (repr(self.group_names[i]) if i < len(self.group_names)
                        else f"<undefined group {i}>")

            kind = ("its own group" if d == g else
                    f"the later group {gname(d)}")
            raise ValueError(
                f"invalid dependency: flow {f} in group {g} ({gname(g)}) "
                f"depends on {kind} (dep={d}); dependencies must point to "
                "strictly earlier groups — this schedule would deadlock")
        return Schedule(path, n_hops, size, group, dep, delay,
                        n_groups=len(self.group_names),
                        group_names=self.group_names)


# ---------------------------------------------------------------------------
# collective algorithms
# ---------------------------------------------------------------------------

def incast(topo: Topology, senders: list, dst: int, size_each: float) -> Schedule:
    b = ScheduleBuilder(topo)
    g = b.new_group("incast")
    for s in senders:
        b.add_flow(s, dst, size_each, g, ecmp_salt=s)
    return b.build()


def _direct_phase(b: ScheduleBuilder, members, seg_bytes, group, dep, delay,
                  salt):
    """Direct (all-to-all-style) phase among ``members``: every member sends
    its segment to every other member simultaneously."""
    for i, u in enumerate(members):
        for j, v in enumerate(members):
            if u == v:
                continue
            b.add_flow(u, v, seg_bytes, group, dep, delay, ecmp_salt=salt + i * 1009 + j)


@register_collective("allreduce_1d", "1d")
def allreduce_1d(topo: Topology, gpus: list, total_bytes: float,
                 n_chunks: int = 4) -> Schedule:
    """Basic direct All-Reduce: RS then AG across all GPUs (paper "1D")."""
    b = ScheduleBuilder(topo)
    P = len(gpus)
    chunk = total_bytes / n_chunks
    seg = chunk / P
    for c in range(n_chunks):
        rs = b.new_group(f"c{c}_rs")
        dep_rs = -1 if c == 0 else rs - 2   # pipeline: chunk c RS after chunk c-1 RS
        _direct_phase(b, gpus, seg, rs, dep_rs, 0.0, salt=c * 7919)
        ag = b.new_group(f"c{c}_ag")
        _direct_phase(b, gpus, seg, ag, rs, 0.0, salt=c * 7919 + 31)
    return b.build()


@register_collective("allreduce_2d", "2d")
def allreduce_2d(topo: Topology, gpus: list, total_bytes: float,
                 n_chunks: int = 4) -> Schedule:
    """Hierarchical All-Reduce (paper "2D"): RS within each node over
    NVLink, RS across same-local-rank GPUs over NICs, then AG in reverse."""
    b = ScheduleBuilder(topo)
    gpn = topo.meta.get("gpus_per_node", 8)
    nodes: dict = {}
    for g in gpus:
        nodes.setdefault(g // gpn, []).append(g)
    node_list = sorted(nodes)
    n_nodes = len(node_list)
    P_local = gpn
    chunk = total_bytes / n_chunks
    # chunk pipelining: chunk c's first stage waits on chunk c-1's *first*
    # stage (same-stage pipeline), tracked explicitly — not on a hardcoded
    # group-id offset
    prev_stage1 = -1
    for c in range(n_chunks):
        g1 = b.new_group(f"c{c}_rs_local")
        dep1 = prev_stage1
        for node in node_list:
            _direct_phase(b, nodes[node], chunk / P_local, g1, dep1, 0.0,
                          salt=c * 7919 + node)
        g2 = b.new_group(f"c{c}_rs_xnode")
        for r in range(P_local):  # same local-rank groups across nodes
            members = [nodes[n][r] for n in node_list]
            _direct_phase(b, members, chunk / (P_local * n_nodes), g2, g1, 0.0,
                          salt=c * 7919 + 101 + r)
        g3 = b.new_group(f"c{c}_ag_xnode")
        for r in range(P_local):
            members = [nodes[n][r] for n in node_list]
            _direct_phase(b, members, chunk / (P_local * n_nodes), g3, g2, 0.0,
                          salt=c * 7919 + 211 + r)
        g4 = b.new_group(f"c{c}_ag_local")
        for node in node_list:
            _direct_phase(b, nodes[node], chunk / P_local, g4, g3, 0.0,
                          salt=c * 7919 + 307 + node)
        prev_stage1 = g1
    return b.build()


@register_collective("alltoall", "a2a")
def alltoall(topo: Topology, gpus: list, total_bytes: float,
             n_chunks: int = 4) -> Schedule:
    """Direct All-To-All: each GPU sends size/P to every other GPU."""
    b = ScheduleBuilder(topo)
    P = len(gpus)
    chunk = total_bytes / n_chunks
    per_pair = chunk / P
    for c in range(n_chunks):
        g = b.new_group(f"c{c}_a2a")
        dep = -1 if c == 0 else g - 1
        _direct_phase(b, gpus, per_pair, g, dep, 0.0, salt=c * 104729)
    return b.build()


def _ring_phase(b: ScheduleBuilder, rings: list, seg_of_ring: list, tag: str,
                dep: int, salt: int):
    """Parallel rings advancing in lockstep: step ``s`` is one group holding
    the i -> i+1 neighbor send of every ring (ring k sends
    ``seg_of_ring[k]`` bytes per step); step s+1 depends on step s.

    Returns ``(first_group, last_group)`` of the chain, or ``(dep, dep)``
    when every ring is trivial (fewer than 2 members)."""
    nsteps = max((len(r) for r in rings), default=0) - 1
    if nsteps < 1:
        return dep, dep
    first = None
    prev = dep
    for s in range(nsteps):
        g = b.new_group(f"{tag}_s{s}")
        for k, ring in enumerate(rings):
            if s >= len(ring) - 1:      # shorter rings finished earlier
                continue
            for i, u in enumerate(ring):
                v = ring[(i + 1) % len(ring)]
                b.add_flow(u, v, seg_of_ring[k], g, prev, 0.0,
                           ecmp_salt=salt + s * 1009 + k * 101 + i)
        if first is None:
            first = g
        prev = g
    return first, prev


@register_collective("allreduce_ring", "ring")
def allreduce_ring(topo: Topology, gpus: list, total_bytes: float,
                   n_chunks: int = 4) -> Schedule:
    """Topology-aware ring All-Reduce: members ordered by GPU id, so
    consecutive ring neighbors are intra-node (NVLink) wherever possible
    and only node-boundary hops cross the NIC fabric.  RS = P-1 neighbor
    steps of S/P each, AG = P-1 more; chunks pipeline on the RS chain."""
    b = ScheduleBuilder(topo)
    members = sorted(gpus)
    P = len(members)
    if P < 2:
        raise ValueError("ring all-reduce needs at least 2 GPUs")
    chunk = total_bytes / n_chunks
    prev_first = -1
    for c in range(n_chunks):
        rs_first, rs_last = _ring_phase(b, [members], [chunk / P],
                                        f"c{c}_rs", prev_first, salt=c * 7919)
        _ring_phase(b, [members], [chunk / P], f"c{c}_ag", rs_last,
                    salt=c * 7919 + 31)
        prev_first = rs_first
    return b.build()


@register_collective("allreduce_hring", "hring")
def allreduce_hring(topo: Topology, gpus: list, total_bytes: float,
                    n_chunks: int = 4) -> Schedule:
    """Hierarchical ring All-Reduce: ring RS inside each node (scale-up
    fabric), ring RS across nodes per local rank (NIC fabric), then the AG
    rings mirror in reverse — the ring counterpart of the paper's 2D
    algorithm, with each direct phase replaced by neighbor rings."""
    b = ScheduleBuilder(topo)
    gpn = topo.meta.get("gpus_per_node", 8)
    nodes: dict = {}
    for g in sorted(gpus):
        nodes.setdefault(g // gpn, []).append(g)
    node_list = sorted(nodes)
    n_nodes = len(node_list)
    local_rings = [nodes[n] for n in node_list]
    # cross-node segment sizing assumes every node holds the same number of
    # members (each rank's post-RS shard is chunk / P_local); uneven nodes
    # would silently mis-size the cross-node traffic
    sizes = {len(r) for r in local_rings}
    if len(sizes) > 1:
        raise ValueError(
            f"hierarchical ring needs equally-populated nodes; got member "
            f"counts {sorted(sizes)} across nodes {node_list}")
    P_local = sizes.pop()
    # cross-node rings: one per local rank, over every node
    xnode_rings = [[nodes[n][r] for n in node_list] for r in range(P_local)]
    chunk = total_bytes / n_chunks
    seg_local = [chunk / P_local] * len(local_rings)
    seg_x = [chunk / (P_local * n_nodes)] * len(xnode_rings)
    prev_first = -1
    for c in range(n_chunks):
        f1, l1 = _ring_phase(b, local_rings, seg_local, f"c{c}_rs_local",
                             prev_first, salt=c * 7919)
        _, l2 = _ring_phase(b, xnode_rings, seg_x, f"c{c}_rs_xnode", l1,
                            salt=c * 7919 + 101)
        _, l3 = _ring_phase(b, xnode_rings, seg_x, f"c{c}_ag_xnode", l2,
                            salt=c * 7919 + 211)
        _ring_phase(b, local_rings, seg_local, f"c{c}_ag_local", l3,
                    salt=c * 7919 + 307)
        prev_first = f1
    return b.build()


def collective_bytes_on_nics(sched: Schedule, topo: Topology) -> float:
    """Bytes crossing scale-out NICs (for 1D-vs-2D traffic checks)."""
    nic = set(int(x) for x in topo.up_link)
    on = np.isin(sched.path, list(nic)).any(axis=1)
    return float((sched.size * on).sum())
