"""System layer: collective algorithms -> dependency-tagged flow schedules.

Mirrors ASTRA-Sim's system layer: each collective is decomposed into
send/recv *messages* (flows); hierarchical algorithms chain stages through
dependency groups; each collective is split into ``n_chunks`` equal chunks
processed in a pipeline (paper §III-D: 4 chunks).

A Schedule is plain numpy; the engine consumes it as static arrays.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.topology import MAXHOP, Topology, route


@dataclasses.dataclass
class Schedule:
    """Flat flow schedule.  All sizes in bytes; times in seconds."""
    path: np.ndarray          # (F, MAXHOP) int32 link ids, -1 pad
    n_hops: np.ndarray        # (F,)
    size: np.ndarray          # (F,) bytes
    group: np.ndarray         # (F,) completion-group id
    dep: np.ndarray           # (F,) dep group id or -1
    delay: np.ndarray         # (F,) start delay after dep completion (s)
    n_groups: int
    group_names: list

    @property
    def n_flows(self) -> int:
        return len(self.size)

    def total_bytes(self) -> float:
        return float(self.size.sum())


class ScheduleBuilder:
    def __init__(self, topo: Topology):
        self.topo = topo
        self.rows: list = []          # (path, size, group, dep, delay)
        self.group_names: list = []

    def new_group(self, name: str) -> int:
        self.group_names.append(name)
        return len(self.group_names) - 1

    def add_flow(self, src: int, dst: int, size: float, group: int,
                 dep: int = -1, delay: float = 0.0, ecmp_salt: int = 0):
        key = (src * 131071 + dst * 8191 + ecmp_salt * 524287 + group) & 0x7FFFFFFF
        p = route(self.topo, src, dst, key)
        self.rows.append((p, size, group, dep, delay))

    def add_marker(self, group: int, dep: int = -1, delay: float = 0.0):
        """Zero-byte flow: pure time/dependency node (compute segments)."""
        self.rows.append(([-1], 0.0, group, dep, delay))

    def build(self) -> Schedule:
        F = len(self.rows)
        path = np.full((F, MAXHOP), -1, np.int32)
        n_hops = np.zeros(F, np.int32)
        size = np.zeros(F, np.float64)
        group = np.zeros(F, np.int32)
        dep = np.full(F, -1, np.int32)
        delay = np.zeros(F, np.float64)
        for i, (p, s, g, d, dl) in enumerate(self.rows):
            if p != [-1]:
                path[i, :len(p)] = p
                n_hops[i] = len(p)
            size[i] = s
            group[i] = g
            dep[i] = d
            delay[i] = dl
        return Schedule(path, n_hops, size, group, dep, delay,
                        n_groups=len(self.group_names),
                        group_names=self.group_names)


# ---------------------------------------------------------------------------
# collective algorithms
# ---------------------------------------------------------------------------

def incast(topo: Topology, senders: list, dst: int, size_each: float) -> Schedule:
    b = ScheduleBuilder(topo)
    g = b.new_group("incast")
    for s in senders:
        b.add_flow(s, dst, size_each, g, ecmp_salt=s)
    return b.build()


def _direct_phase(b: ScheduleBuilder, members, seg_bytes, group, dep, delay,
                  salt):
    """Direct (all-to-all-style) phase among ``members``: every member sends
    its segment to every other member simultaneously."""
    for i, u in enumerate(members):
        for j, v in enumerate(members):
            if u == v:
                continue
            b.add_flow(u, v, seg_bytes, group, dep, delay, ecmp_salt=salt + i * 1009 + j)


def allreduce_1d(topo: Topology, gpus: list, total_bytes: float,
                 n_chunks: int = 4) -> Schedule:
    """Basic direct All-Reduce: RS then AG across all GPUs (paper "1D")."""
    b = ScheduleBuilder(topo)
    P = len(gpus)
    chunk = total_bytes / n_chunks
    seg = chunk / P
    for c in range(n_chunks):
        rs = b.new_group(f"c{c}_rs")
        dep_rs = -1 if c == 0 else rs - 2   # pipeline: chunk c RS after chunk c-1 RS
        _direct_phase(b, gpus, seg, rs, dep_rs, 0.0, salt=c * 7919)
        ag = b.new_group(f"c{c}_ag")
        _direct_phase(b, gpus, seg, ag, rs, 0.0, salt=c * 7919 + 31)
    return b.build()


def allreduce_2d(topo: Topology, gpus: list, total_bytes: float,
                 n_chunks: int = 4) -> Schedule:
    """Hierarchical All-Reduce (paper "2D"): RS within each node over
    NVLink, RS across same-local-rank GPUs over NICs, then AG in reverse."""
    b = ScheduleBuilder(topo)
    gpn = topo.meta.get("gpus_per_node", 8)
    nodes: dict = {}
    for g in gpus:
        nodes.setdefault(g // gpn, []).append(g)
    node_list = sorted(nodes)
    n_nodes = len(node_list)
    P_local = gpn
    chunk = total_bytes / n_chunks
    prev_tail = -1
    for c in range(n_chunks):
        g1 = b.new_group(f"c{c}_rs_local")
        dep1 = prev_tail if c > 0 else -1
        # actually pipeline on the same stage of previous chunk:
        dep1 = -1 if c == 0 else g1 - 4
        for node in node_list:
            _direct_phase(b, nodes[node], chunk / P_local, g1, dep1, 0.0,
                          salt=c * 7919 + node)
        g2 = b.new_group(f"c{c}_rs_xnode")
        for r in range(P_local):  # same local-rank groups across nodes
            members = [nodes[n][r] for n in node_list]
            _direct_phase(b, members, chunk / (P_local * n_nodes), g2, g1, 0.0,
                          salt=c * 7919 + 101 + r)
        g3 = b.new_group(f"c{c}_ag_xnode")
        for r in range(P_local):
            members = [nodes[n][r] for n in node_list]
            _direct_phase(b, members, chunk / (P_local * n_nodes), g3, g2, 0.0,
                          salt=c * 7919 + 211 + r)
        g4 = b.new_group(f"c{c}_ag_local")
        for node in node_list:
            _direct_phase(b, nodes[node], chunk / P_local, g4, g3, 0.0,
                          salt=c * 7919 + 307 + node)
        prev_tail = g1
    return b.build()


def alltoall(topo: Topology, gpus: list, total_bytes: float,
             n_chunks: int = 4) -> Schedule:
    """Direct All-To-All: each GPU sends size/P to every other GPU."""
    b = ScheduleBuilder(topo)
    P = len(gpus)
    chunk = total_bytes / n_chunks
    per_pair = chunk / P
    for c in range(n_chunks):
        g = b.new_group(f"c{c}_a2a")
        dep = -1 if c == 0 else g - 1
        _direct_phase(b, gpus, per_pair, g, dep, 0.0, salt=c * 104729)
    return b.build()


def collective_bytes_on_nics(sched: Schedule, topo: Topology) -> float:
    """Bytes crossing scale-out NICs (for 1D-vs-2D traffic checks)."""
    nic = set(int(x) for x in topo.up_link)
    on = np.isin(sched.path, list(nic)).any(axis=1)
    return float((sched.size * on).sum())
