"""Fault-injection layer: declarative fabric faults as a traced pytree.

The paper evaluates CC policies only on a healthy, lossless, private
fabric — exactly the regime where it finds CC barely matters.  Follow-up
work shows the interesting behavior appears once that assumption breaks:
Mittal et al. ("Revisiting Network Support for RDMA") make RoCE *lossy*
with IRN-style selective retransmit, and Hoefler et al. ("Issues at
Hyperscale") catalogue flapping/degraded links, pause storms and PFC
deadlock cycles.  ``FaultSpec`` injects those regimes into the fluid
engine as *time-scheduled, traced* events:

* **random packet loss** (``loss_rate``) on fabric links, with per-flow
  loss accounting and a recovery model — IRN selective retransmit
  (``gbn=0``: only the lost bytes re-enter the flow's remaining work) vs
  go-back-N (``gbn=1``: each loss additionally resends ~half the
  in-flight window, modelled via ``mtu`` packetization);
* **link degradation** (``degrade`` capacity scaling, per link class,
  active over the ``[degrade_t0, degrade_t1)`` window);
* **link flaps** (``flap_period``/``flap_down``: fabric links go down for
  ``flap_down`` seconds out of every ``flap_period``, starting at
  ``flap_t0``);
* **ECN / PFC misconfiguration** (``ecn_scale`` scales marking
  probability — 0 = broken ECN; ``pfc_on=0`` disables PFC pausing, the
  lossy-RoCE operating point).

Like ``engine.FabricParams``, a ``FaultSpec`` is a registered-dataclass
pytree whose leaves are either scalars or per-link-class arrays (indexed
by ``topology.LINK_CLASSES``), so fault grids ride the existing
one-dispatch vmap path in ``SweepRunner`` (``stacked_fault`` /
``fault_grid``) and carry on ``ScenarioSpec.fault_spec``.

The all-defaults spec is *statically* inert: ``is_faulty`` inspects the
concrete leaves and the engine compiles the historical fault-free step
when it returns False, so lossless defaults stay bitwise-identical to the
PR-2 engine goldens.
"""
from __future__ import annotations

import dataclasses
import enum

import jax
import numpy as np

from repro.core.cc import ParamSpec
from repro.core.topology import LINK_CLASS_ID, N_LINK_CLASSES


class LaneStatus(str, enum.Enum):
    """Typed health verdict of one simulated lane (or serial run).

    A ``str`` subclass so every existing consumer keeps working unchanged:
    ``status == "ok"`` compares by value, ``json.dump`` serializes to the
    plain string, and CSV writers emit the bare label.  The precedence in
    ``classify_lane`` mirrors the historical ad-hoc classification:
    divergence trumps everything (the lane was frozen at the first
    non-finite state, nothing after it is meaningful), an unfinished lane
    with a detected pause cycle is ``DEADLOCKED``, an unfinished lane
    without one ran out of step budget (``EXHAUSTED``), and a finished
    lane that saw a pause cycle still reads ``DEADLOCKED`` — the cycle
    resolved only because flows drained.
    """
    OK = "ok"
    DIVERGED = "diverged"
    DEADLOCKED = "deadlocked"
    EXHAUSTED = "exhausted"

    def __str__(self) -> str:          # f"{status}" -> "ok", not "LaneStatus.OK"
        return self.value


def classify_lane(diverged: bool, deadlocked: bool,
                  finished: bool) -> LaneStatus:
    """Map the engine's run-health observers onto one ``LaneStatus``."""
    if diverged:
        return LaneStatus.DIVERGED
    if deadlocked:
        return LaneStatus.DEADLOCKED
    if not finished:
        return LaneStatus.EXHAUSTED
    return LaneStatus.OK

_FAULT_DEFAULTS = dict(
    loss_rate=0.0, gbn=0.0, mtu=4096.0,
    degrade=1.0, degrade_t0=0.0, degrade_t1=0.0,
    flap_period=0.0, flap_down=0.0, flap_t0=0.0,
    ecn_scale=1.0, pfc_on=1.0,
)

# declarative search spaces for the sweepable fault knobs — the same
# ParamSpec currency as CC policies and FABRIC_PARAM_SPECS, consumed by
# ``sweep.grid_from_spec``-style drivers and the fault-regime figure
FAULT_PARAM_SPECS = {
    "loss_rate": ParamSpec(0.0, lo=0.0, hi=0.1, scale="linear"),
    "gbn": ParamSpec(0.0, lo=0.0, hi=1.0, integer=True),
    "mtu": ParamSpec(4096.0, lo=256.0, hi=9000.0, scale="log"),
    "degrade": ParamSpec(1.0, lo=0.01, hi=1.0, scale="linear"),
    "flap_period": ParamSpec(0.0, lo=0.0, hi=1.0, scale="linear"),
    "flap_down": ParamSpec(0.0, lo=0.0, hi=1.0, scale="linear"),
    "ecn_scale": ParamSpec(1.0, lo=0.0, hi=2.0, scale="linear"),
    "pfc_on": ParamSpec(1.0, lo=0.0, hi=1.0, integer=True),
}

RECOVERY_MODES = ("irn", "gbn")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Time-scheduled fabric faults: a pytree traced alongside cc_params.

    Leaves are scalars or per-link-class ``(N_LINK_CLASSES,)`` arrays
    (``loss_rate``/``degrade``/``ecn_scale``/``pfc_on``), so e.g. only
    spine downlinks can be lossy.  Loss and flaps apply to *fabric* links
    only (NVLink is never lossy).  ``gbn`` selects the loss-recovery
    model as a traced float (0 = IRN selective retransmit, 1 = go-back-N)
    so both recovery modes sweep in one vmapped dispatch.  The default
    instance is statically inert (see ``is_faulty``): the engine compiles
    the historical fault-free step for it.
    """
    loss_rate: object = 0.0        # per-packet drop probability, fabric links
    gbn: object = 0.0              # recovery: 0 = IRN, 1 = go-back-N (traced)
    mtu: object = 4096.0           # packetization for the GBN resend model (B)
    degrade: object = 1.0          # capacity multiplier while degraded
    degrade_t0: object = 0.0       # degradation window [t0, t1) in seconds
    degrade_t1: object = 0.0
    flap_period: object = 0.0      # flap cycle length (s); 0 = no flapping
    flap_down: object = 0.0        # down time at the start of each cycle (s)
    flap_t0: object = 0.0          # first flap onset (s)
    ecn_scale: object = 1.0        # ECN marking-probability multiplier
    pfc_on: object = 1.0           # 0 disables PFC pausing (lossy RoCE)

    FIELDS = ("loss_rate", "gbn", "mtu", "degrade", "degrade_t0",
              "degrade_t1", "flap_period", "flap_down", "flap_t0",
              "ecn_scale", "pfc_on")

    @classmethod
    def lossy_roce(cls, loss_rate: float, recovery: str = "irn",
                   pfc_on: bool = False, **kw) -> "FaultSpec":
        """The Mittal et al. operating point: random loss, PFC off, and a
        named recovery model ("irn" selective retransmit or "gbn")."""
        if recovery not in RECOVERY_MODES:
            raise ValueError(f"unknown recovery {recovery!r}; "
                             f"choose from {RECOVERY_MODES}")
        return cls(loss_rate=loss_rate, gbn=float(recovery == "gbn"),
                   pfc_on=float(bool(pfc_on)), **kw)

    @classmethod
    def check_fields(cls, keys):
        """Reject names that are not FaultSpec fields."""
        unknown = set(keys) - set(cls.FIELDS)
        if unknown:
            raise ValueError(f"unknown fault params {sorted(unknown)}; "
                             f"known: {list(cls.FIELDS)}")

    def replace(self, **kw) -> "FaultSpec":
        return dataclasses.replace(self, **kw)

    def with_class(self, **field_overrides) -> "FaultSpec":
        """Per-link-class overrides, mirroring ``FabricParams.with_class``:
        ``FaultSpec().with_class(loss_rate={"spine_down": 1e-3})``."""
        out = {}
        for field, overrides in field_overrides.items():
            base = np.broadcast_to(
                np.asarray(getattr(self, field), np.float32),
                (N_LINK_CLASSES,)).copy()
            for cls_name, v in overrides.items():
                base[LINK_CLASS_ID[cls_name]] = v
            out[field] = base
        return dataclasses.replace(self, **out)


jax.tree_util.register_dataclass(FaultSpec,
                                 data_fields=FaultSpec.FIELDS,
                                 meta_fields=())


def _as_fault(fault_spec) -> FaultSpec:
    return FaultSpec() if fault_spec is None else fault_spec


def is_faulty(flt: FaultSpec) -> bool:
    """Static predicate: does this spec (or stacked batch of specs) inject
    any fault at all?  Evaluated on concrete leaves at dispatch time; the
    engine keys its compile cache on the result, so the all-defaults spec
    runs the historical fault-free step (bitwise-identical goldens) and
    traced fault knobs only exist in executables that need them."""
    for f in FaultSpec.FIELDS:
        v = np.asarray(getattr(flt, f))
        if not np.all(v == _FAULT_DEFAULTS[f]):
            return True
    return False
