"""Declarative scenario layer: every simulation point as one spec.

The paper's result set is a sweep over CC policy x collective x topology x
fabric tuning (Figs 3-11), and follow-up work (Hoefler et al., Mittal et
al.) shows conclusions hinge on fabric parameters.  This module makes each
point of that space a value, not a code path:

    FabricSpec    -- topology family + BW/latency/buffer/oversubscription
                     (built via the TOPOLOGIES registry, cached by value)
    ScenarioSpec  -- fabric x workload x CC policy x FabricParams

Workloads are anything with ``build_schedule(topo) -> Schedule``:
``CollectiveSpec`` enumerates the registered collective algorithms
(``collectives.COLLECTIVES`` -- the paper's 1D/2D/ring/a2a axis),
``IncastSpec`` covers the microbenchmarks, and the workload layer adds
``DLRMIterationSpec`` (repro.core.workload) / ``HLOReplaySpec``
(repro.core.predict).

``SweepRunner`` (repro.core.sweep) consumes specs directly: same-shaped
specs share compiled engines, and CC x fabric parameter grids batch
through one vmapped dispatch.

    spec = ScenarioSpec(fabric=FabricSpec(n_racks=2),
                        workload=CollectiveSpec("ring", 64e6),
                        policy="dcqcn")
    res = spec.run()
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.cc import get_policy, stack_policies
from repro.core.collectives import Schedule, get_collective, incast
from repro.core.engine import EngineConfig, FabricParams
from repro.core.topology import (NIC_BW, NIC_LAT, NVLINK_BW, NVLINK_LAT,
                                 SWITCH_BUF, Topology)
from repro.core import topology as topo_mod

# ---------------------------------------------------------------------------
# topology-family registry
# ---------------------------------------------------------------------------

TOPOLOGIES: dict[str, Callable] = {}


def register_topology(name: str):
    """Register ``fn(spec: FabricSpec) -> Topology`` under ``name``."""
    def deco(fn):
        if name in TOPOLOGIES:
            raise ValueError(f"topology family {name!r} already registered")
        TOPOLOGIES[name] = fn
        return fn
    return deco


@dataclasses.dataclass(frozen=True)
class FabricSpec:
    """Declarative fabric: family + scale + link speeds + oversubscription.

    ``n_spines=None`` derives the spine count from ``oversubscription``:
    full bisection gives every ToR one uplink per NIC downlink
    (``nodes_per_rack * gpus_per_node`` spines); oversubscription > 1
    divides that (e.g. 2.0 -> half the spines, the Fig-5 imbalance regime).
    """
    family: str = "clos"
    n_racks: int = 2
    nodes_per_rack: int = 2
    gpus_per_node: int = 8
    n_spines: int | None = None
    oversubscription: float = 1.0
    nic_bw: float = NIC_BW
    nic_lat: float = NIC_LAT
    nv_bw: float = NVLINK_BW
    nv_lat: float = NVLINK_LAT
    buf: float = SWITCH_BUF

    @property
    def n_gpus(self) -> int:
        return self.n_racks * self.nodes_per_rack * self.gpus_per_node

    @property
    def spine_count(self) -> int:
        if self.n_spines is not None:
            return self.n_spines
        full = self.nodes_per_rack * self.gpus_per_node
        return max(1, round(full / self.oversubscription))

    def build(self) -> Topology:
        """Build (or fetch the cached) Topology for this spec."""
        topo = _TOPO_CACHE.get(self)
        if topo is None:
            try:
                builder = TOPOLOGIES[self.family]
            except KeyError:
                raise KeyError(f"unknown topology family {self.family!r}; "
                               f"registered: {sorted(TOPOLOGIES)}") from None
            topo = builder(self)
            while len(_TOPO_CACHE) >= _TOPO_CACHE_MAX:
                _TOPO_CACHE.pop(next(iter(_TOPO_CACHE)))
            _TOPO_CACHE[self] = topo
        return topo


_TOPO_CACHE: dict = {}
_TOPO_CACHE_MAX = 32
# (FabricSpec, workload) -> Schedule; Schedules are plain frozen numpy
_SCHED_CACHE: dict = {}
_SCHED_CACHE_MAX = 64


@register_topology("clos")
def _build_clos(spec: FabricSpec) -> Topology:
    return topo_mod.clos(n_racks=spec.n_racks,
                         nodes_per_rack=spec.nodes_per_rack,
                         gpus_per_node=spec.gpus_per_node,
                         n_spines=spec.spine_count,
                         nic_bw=spec.nic_bw, nic_lat=spec.nic_lat,
                         nv_bw=spec.nv_bw, nv_lat=spec.nv_lat,
                         buf=spec.buf)


@register_topology("single")
def _build_single(spec: FabricSpec) -> Topology:
    return topo_mod.single_switch(spec.n_gpus, bw=spec.nic_bw,
                                  lat=spec.nic_lat, buf=spec.buf)


# ---------------------------------------------------------------------------
# workload specs (anything with build_schedule(topo) -> Schedule)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CollectiveSpec:
    """One collective from the registry over all (or selected) GPUs."""
    kind: str                      # name in collectives.COLLECTIVES
    total_bytes: float
    n_chunks: int = 4
    gpus: tuple | None = None      # None -> every fabric GPU

    def build_schedule(self, topo: Topology) -> Schedule:
        gpus = (list(self.gpus) if self.gpus is not None
                else list(range(topo.n_gpus)))
        return get_collective(self.kind)(topo, gpus, self.total_bytes,
                                         n_chunks=self.n_chunks)


@dataclasses.dataclass(frozen=True)
class IncastSpec:
    """The paper's Fig-3 microbenchmark: N senders into one receiver."""
    n_senders: int
    size_each: float
    dst: int = 0

    def build_schedule(self, topo: Topology) -> Schedule:
        senders = [g for g in range(topo.n_gpus) if g != self.dst]
        if len(senders) < self.n_senders:
            raise ValueError(
                f"IncastSpec wants {self.n_senders} senders but the fabric "
                f"has only {len(senders)} GPUs besides dst={self.dst}")
        return incast(topo, senders[:self.n_senders], self.dst,
                      self.size_each)


# ---------------------------------------------------------------------------
# the scenario spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One fully-specified simulation point.

    ``policy`` is a registry name, a ``Policy``, or a *tuple* of either —
    a tuple declares a whole policy axis, built into one stacked product
    policy (``cc.stack_policies``) whose lanes batch through a single
    vmapped dispatch (``SweepRunner.grid_spec`` / ``run_policy_axis``).
    ``cc_params``, ``fabric_params`` and ``fault_spec`` are traced per-run
    overrides, so specs differing only there share one compiled engine
    (and can be batched -- see ``SweepRunner.grid_spec``); ``fault_spec``
    (``faults.FaultSpec``) declares the fault regime the scenario runs
    under — loss, flaps, degradation, ECN/PFC misconfiguration — and
    defaults to the lossless healthy fabric.  ``fabric`` is normally a
    declarative ``FabricSpec``; a prebuilt ``Topology`` is also accepted
    so callers holding one (tests, calibration drivers) can still ride
    the spec path.
    """
    fabric: object                 # FabricSpec | Topology
    workload: object               # has build_schedule(topo) -> Schedule
    policy: object = "pfc"         # str | Policy | tuple (policy axis)
    cc_params: dict | None = None
    fabric_params: FabricParams | None = None
    fault_spec: object | None = None   # faults.FaultSpec | None (= healthy)
    name: str = ""

    def build(self):
        """-> (topo, sched, policy).  Topology construction is cached by
        FabricSpec value, and schedules are memoized by (FabricSpec,
        workload) value when both are hashable — a per-policy spec list
        over one workload routes each flow once, not once per policy."""
        topo = (self.fabric if isinstance(self.fabric, Topology)
                else self.fabric.build())
        key = None
        if isinstance(self.fabric, FabricSpec):
            try:
                hash(self.workload)
                key = (self.fabric, self.workload)
            except TypeError:
                key = None          # unhashable workload: rebuild each time
        sched = _SCHED_CACHE.get(key) if key is not None else None
        if sched is None:
            sched = self.workload.build_schedule(topo)
            if key is not None:
                while len(_SCHED_CACHE) >= _SCHED_CACHE_MAX:
                    _SCHED_CACHE.pop(next(iter(_SCHED_CACHE)))
                _SCHED_CACHE[key] = sched
        if isinstance(self.policy, (tuple, list)):
            pol = stack_policies(self.policy)
        elif isinstance(self.policy, str):
            pol = get_policy(self.policy)
        else:
            pol = self.policy
        return topo, sched, pol

    def run(self, runner=None, cfg: EngineConfig | None = None):
        """Simulate this spec (convenience; prefer a shared SweepRunner).
        A tuple-policy spec (``scenario_matrix(stacked=True)``) runs its
        whole policy axis as one batched — and, when the runner has a
        device mesh, sharded — dispatch and returns ``BatchResults``
        instead of ``Results``."""
        from repro.core.sweep import SweepRunner
        runner = runner or SweepRunner(cfg)
        if isinstance(self.policy, (tuple, list)):
            return runner.grid_spec(self, cfg=cfg)
        return runner.run_spec(self, cfg=cfg)


def scenario_matrix(fabrics, workloads, policies,
                    fabric_params=None, stacked=False,
                    fault_spec=None) -> list[ScenarioSpec]:
    """Cross-product helper: the paper's per-figure loops as one list.

    ``stacked=True`` folds the policy dimension into each spec instead of
    enumerating it: one spec per (fabric, workload) whose ``policy`` is the
    whole tuple, so ``SweepRunner`` runs the comparison as one vmapped
    policy-axis dispatch rather than a serial per-policy loop.
    ``fault_spec`` applies one fault regime to every generated spec.
    """
    fabrics = [fabrics] if isinstance(fabrics, (FabricSpec, Topology)) \
        else list(fabrics)
    out = []
    for fab in fabrics:
        fname = (f"{fab.family}{fab.n_gpus}" if isinstance(fab, FabricSpec)
                 else fab.name)
        for wl in workloads:
            wname = getattr(wl, "kind", type(wl).__name__)
            if stacked:
                out.append(ScenarioSpec(
                    fabric=fab, workload=wl, policy=tuple(policies),
                    fabric_params=fabric_params, fault_spec=fault_spec,
                    name=f"{fname}_{wname}_stack"))
                continue
            for pol in policies:
                pname = pol if isinstance(pol, str) else pol.name
                out.append(ScenarioSpec(
                    fabric=fab, workload=wl, policy=pol,
                    fabric_params=fabric_params, fault_spec=fault_spec,
                    name=f"{fname}_{wname}_{pname}"))
    return out
