"""Fault-tolerance runtime: checkpoint/restart, straggler detection,
failure injection, elastic re-sharding.

On a real 1000+-node cluster the *policies* here drive the control plane
(job restart, hot-spare swap, mesh shrink); the mechanisms themselves
(deterministic data stream, atomic checkpoints, device_put re-sharding)
are the same ones exercised by the CPU tests.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore

log = logging.getLogger("repro.ft")


class FailureInjector:
    """Deterministic failure injection for tests: raises once at step N."""

    def __init__(self, fail_at_steps: tuple[int, ...] = ()):
        self.fail_at = set(fail_at_steps)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


class StragglerDetector:
    """EMA + z-score over per-step wall times.

    On a cluster, per-host step times arrive via the heartbeat channel; a
    sustained z>k host is reported for hot-spare replacement.  Here the
    detector is fed locally and its *decisions* are unit-tested.
    """

    def __init__(self, window: int = 50, z_threshold: float = 3.0,
                 patience: int = 3):
        self.times: list[float] = []
        self.window = window
        self.z = z_threshold
        self.patience = patience
        self._strikes = 0
        self.flagged: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler event."""
        hist = self.times[-self.window:]
        self.times.append(dt)
        if len(hist) < 8:
            return False
        mu = float(np.mean(hist))
        sd = float(np.std(hist)) + 1e-9
        if (dt - mu) / sd > self.z:
            self._strikes += 1
            if self._strikes >= self.patience:
                self.flagged.append(step)
                self._strikes = 0
                log.warning("straggler flagged at step %d (%.3fs vs mu %.3fs)",
                            step, dt, mu)
                return True
        else:
            self._strikes = 0
        return False


def reshard(tree, new_mesh, specs):
    """Elastic re-shard: lay a pytree out on a different mesh."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
        tree, specs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P))


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    checkpoint_every: int = 50
    keep: int = 3
    max_restarts: int = 3


class TrainRunner:
    """Crash-safe training loop.

    train_step_fn(params, opt_state, batch) -> (params, opt_state, metrics)
    make_batch(step) -> device batch (must be deterministic in step!)
    """

    def __init__(self, cfg: RunnerConfig, train_step_fn: Callable,
                 make_batch: Callable[[int], Any],
                 injector: FailureInjector | None = None,
                 straggler: StragglerDetector | None = None):
        self.cfg = cfg
        self.train_step = train_step_fn
        self.make_batch = make_batch
        self.injector = injector or FailureInjector()
        self.straggler = straggler or StragglerDetector()
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.restarts = 0
        self.metrics_log: list[dict] = []

    def _restore_or(self, params, opt_state):
        step = latest_step(self.cfg.ckpt_dir)
        if step is None:
            return params, opt_state, 0
        (params, opt_state), meta = restore(
            self.cfg.ckpt_dir, step, (params, opt_state))
        log.info("restored checkpoint at step %d", step)
        return params, opt_state, int(meta.get("next_step", step))

    def run(self, params, opt_state, n_steps: int):
        params, opt_state, start = self._restore_or(params, opt_state)
        step = start
        while step < n_steps:
            try:
                t0 = time.monotonic()
                self.injector.maybe_fail(step)
                batch = self.make_batch(step)
                params, opt_state, metrics = self.train_step(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.monotonic() - t0
                self.straggler.observe(step, dt)
                self.metrics_log.append(
                    {"step": step, "dt": dt,
                     **{k: float(v) for k, v in metrics.items()}})
                step += 1
                if step % self.cfg.checkpoint_every == 0 or step == n_steps:
                    self.ckpt.save(step, (params, opt_state),
                                   extra_meta={"next_step": step})
            except RuntimeError as e:
                self.restarts += 1
                log.warning("step %d failed (%s); restart %d", step, e, self.restarts)
                if self.restarts > self.cfg.max_restarts:
                    raise
                params, opt_state, step = self._restore_or(params, opt_state)
        self.ckpt.wait()
        return params, opt_state
