"""Warm-start caches shared by the benchmark/figure drivers.

The JAX persistent compilation cache keeps XLA executables on disk, so a
fresh process re-running an already-compiled sweep (the 21 s policy-axis
cold compile, the 3.3 s headline) loads the binary instead of
recompiling.  ``enable_compilation_cache()`` points the process at
``$REPRO_CACHE_DIR/jax_compilation`` (default ``.cache/jax_compilation``
— the same root ``repro.core.sweep`` uses for persisted backend
calibrations); CI caches the directory between runs.  Disable with
``REPRO_COMPILATION_CACHE=0``.
"""
from __future__ import annotations

import os


def default_cache_dir() -> str:
    return os.path.join(os.environ.get("REPRO_CACHE_DIR", ".cache"),
                        "jax_compilation")


def enable_compilation_cache(cache_dir: str | None = None,
                             min_compile_secs: float = 0.2) -> str | None:
    """Enable the JAX persistent compilation cache at ``cache_dir``.

    Returns the directory in use, or None when disabled
    (``REPRO_COMPILATION_CACHE=0``) or unavailable (unwritable dir, jax
    without the config knob).  Safe to call more than once; the last
    directory wins.  ``min_compile_secs`` skips persisting trivial
    compiles so the cache holds the executables worth warm-starting.
    """
    if os.environ.get("REPRO_COMPILATION_CACHE", "1") == "0":
        return None
    import jax
    cache_dir = cache_dir or default_cache_dir()
    try:
        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs",
                          float(min_compile_secs))
    except (OSError, AttributeError, ValueError):
        return None
    return cache_dir


def compilation_cache_entries(cache_dir: str | None = None) -> int:
    """Number of persisted executables currently in the cache dir."""
    cache_dir = cache_dir or default_cache_dir()
    try:
        return sum(1 for n in os.listdir(cache_dir)
                   if not n.startswith("."))
    except OSError:
        return 0
