"""Mixed-precision policy: params/compute/accumulation dtypes."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: jnp.dtype = jnp.dtype(jnp.float32)
    compute_dtype: jnp.dtype = jnp.dtype(jnp.bfloat16)
    accum_dtype: jnp.dtype = jnp.dtype(jnp.float32)
    # optimizer master/moment dtype; bf16 for the giant MoE cells (see DESIGN)
    opt_dtype: jnp.dtype = jnp.dtype(jnp.float32)

    def cast_compute(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.compute_dtype)
            if hasattr(x, "astype") and jnp.issubdtype(x.dtype, jnp.floating)
            else x,
            tree,
        )


BF16_TRAIN = Policy(param_dtype=jnp.dtype(jnp.bfloat16))
F32_PARAMS = Policy()
# memory-frugal policy for 100B+ MoE training cells
BF16_EVERYTHING = Policy(
    param_dtype=jnp.dtype(jnp.bfloat16), opt_dtype=jnp.dtype(jnp.bfloat16)
)
