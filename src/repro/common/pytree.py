"""Declarative parameter trees.

Models declare their parameters once as a pytree of :class:`ParamDef`
(shape + dtype + init + logical axes).  From that single declaration we
derive:

* ``materialize(defs, key)``  -> pytree of initialized ``jnp`` arrays
* ``specs_of(defs, rules)``   -> matching pytree of ``PartitionSpec``
* ``abstract(defs)``          -> matching pytree of ``ShapeDtypeStruct``

keeping init / sharding / dry-run shapes impossible to diverge.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """A single parameter: shape, dtype, initializer and *logical* axes.

    ``axes`` names one logical axis per dim (or None for unsharded), e.g.
    ``("vocab", "embed")``.  Mesh mapping happens later via MeshRules.
    """

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | scaled
    dtype: Any = jnp.float32
    scale: float | None = None  # override init scale

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_one(d: ParamDef, key: jax.Array) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "normal":
        scale = d.scale if d.scale is not None else 0.02
        return (scale * jax.random.normal(key, d.shape)).astype(d.dtype)
    if d.init == "scaled":  # fan-in scaled (lecun normal)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        scale = d.scale if d.scale is not None else 1.0
        return (scale / math.sqrt(max(fan_in, 1)) * jax.random.normal(key, d.shape)).astype(d.dtype)
    raise ValueError(f"unknown init {d.init}")


def materialize(defs, key: jax.Array):
    """Initialize every ParamDef leaf with a folded-in unique key."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    out = []
    for i, leaf in enumerate(leaves):
        if _is_def(leaf):
            out.append(_init_one(leaf, jax.random.fold_in(key, i)))
        else:
            out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def abstract(defs):
    """ShapeDtypeStruct tree (used by the dry-run: no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=_is_def
    )


def specs_of(defs, rules: "Any"):
    """PartitionSpec tree via MeshRules (import-cycle-free duck typing).
    Shape-aware: non-divisible assignments fall back to replication."""
    return jax.tree.map(lambda d: rules.pspec(d.axes, d.shape), defs,
                        is_leaf=_is_def)


def count_params(defs_or_params) -> int:
    total = 0
    for leaf in jax.tree.leaves(defs_or_params, is_leaf=_is_def):
        if _is_def(leaf):
            total += int(np.prod(leaf.shape))
        elif hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape))
    return total


def tree_bytes(defs_or_params) -> int:
    total = 0
    for leaf in jax.tree.leaves(defs_or_params, is_leaf=_is_def):
        if _is_def(leaf):
            total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        elif hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total


def flatten_with_paths(tree, is_leaf: Callable | None = None):
    """[(dot.path, leaf)] for checkpointing / inspection."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)
    out = []
    for path, leaf in flat:
        name = ".".join(_path_elem(p) for p in path)
        out.append((name, leaf))
    return out


def _path_elem(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)
