"""Logical-axis -> mesh-axis sharding rules (GSPMD style).

Logical axes used across the model zoo:

  batch    activations' batch dim          -> ("pod", "data")
  seq      sequence dim of *caches*        -> "data" (sequence parallelism
           for long-context decode; activations keep seq unsharded)
  vocab    embedding / logits vocab dim    -> "model"
  embed    d_model dim                     -> None (or "data" under FSDP)
  heads    attention heads                 -> "model"
  kv_heads KV heads                        -> "model" when divisible
  mlp      FFN hidden dim                  -> "model"
  expert   MoE expert dim                  -> "model"  (expert parallelism)
  layers   scan-stacked layer dim          -> None
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": ("data",),
    "vocab": ("model",),
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "mla_latent": ("model",),
    "layers": None,
    "conv": None,
    "state": None,
}

# FSDP variant: additionally shard the d_model dim of weights over "data"
FSDP_RULES = dict(DEFAULT_RULES, embed=("data",))


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Maps logical axis names to mesh axes, restricted to a given mesh.

    When a concrete ``shape`` is provided, assignments that don't divide
    the dimension are dropped (rightmost mesh axis first) — e.g. kv_heads=4
    on a model=16 mesh falls back to replication (the standard
    KV-replication strategy for GQA under wide TP)."""

    rules: tuple[tuple[str, tuple[str, ...] | None], ...]
    mesh_axes: tuple[str, ...]
    axis_sizes: tuple[tuple[str, int], ...]

    @classmethod
    def create(cls, mesh: Mesh, overrides: dict | None = None) -> "MeshRules":
        rules = dict(DEFAULT_RULES)
        if overrides:
            rules.update(overrides)
        # Drop mesh axes that don't exist on this mesh (e.g. no "pod").
        clean = {}
        for k, v in rules.items():
            if v is None or v == ():
                clean[k] = None
            else:
                kept = tuple(a for a in v if a in mesh.axis_names)
                clean[k] = kept if kept else None
        shape = mesh.shape  # dict-like on both Mesh and AbstractMesh
        sizes = tuple((a, int(shape[a])) for a in mesh.axis_names)
        return cls(tuple(sorted(clean.items())), tuple(mesh.axis_names), sizes)

    def _lookup(self, logical: str | None):
        if logical is None:
            return None
        for k, v in self.rules:
            if k == logical:
                return v
        return None

    def _size(self, axis: str) -> int:
        for k, v in self.axis_sizes:
            if k == axis:
                return v
        return 1

    def pspec(self, axes: tuple[str | None, ...],
              shape: tuple[int, ...] | None = None) -> P:
        used: set[str] = set()
        parts = []
        for i, a in enumerate(axes):
            m = self._lookup(a)
            if m is None:
                parts.append(None)
                continue
            kept = tuple(x for x in m if x not in used)
            if shape is not None:
                # drop axes (rightmost first) until the dim divides evenly
                dim = shape[i]
                while kept and dim % _prod(self._size(x) for x in kept) != 0:
                    kept = kept[:-1]
            used.update(kept)
            if not kept:
                parts.append(None)
            elif len(kept) == 1:
                parts.append(kept[0])
            else:
                parts.append(kept)
        # strip trailing Nones for tidiness
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)


def _prod(it) -> int:
    n = 1
    for x in it:
        n *= x
    return n


def logical_to_pspec(axes: tuple[str | None, ...], mesh: Mesh,
                     overrides: dict | None = None) -> P:
    return MeshRules.create(mesh, overrides).pspec(axes)


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def shard_tree(tree_specs, mesh: Mesh):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# 1-D grid meshes for sharded sweep execution (repro.core.sweep)
# ---------------------------------------------------------------------------

GRID_AXIS = "grid"


def grid_mesh(n_devices: int | None = None, axis: str = GRID_AXIS,
              devices=None) -> Mesh | None:
    """A 1-D mesh over local devices for laying out a sweep's grid axis.

    Returns ``None`` when fewer than two devices are available (callers
    fall back to the single-device vmap path).  On a CPU-only host, JAX
    emulates a multi-device platform under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the testing
    recipe for the sharded sweep path.
    """
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices) if n_devices is None else int(n_devices)
    if n > len(devices):
        raise ValueError(f"grid_mesh wants {n} devices but only "
                         f"{len(devices)} are available")
    if n < 2:
        return None
    import numpy as np
    return Mesh(np.asarray(devices[:n]), (axis,))


def resolve_grid_mesh(mesh, axis: str = GRID_AXIS) -> Mesh | None:
    """Normalize a user-facing mesh argument to a 1-D ``Mesh`` or ``None``.

    Accepts ``None`` (single-device), ``"auto"`` (all local devices, or
    ``None`` when only one exists), an int device count, or a prebuilt
    1-D ``Mesh``."""
    if mesh is None:
        return None
    if isinstance(mesh, Mesh):
        if len(mesh.axis_names) != 1:
            raise ValueError("sweep sharding wants a 1-D mesh (one grid "
                             f"axis); got axes {mesh.axis_names}")
        return mesh if _mesh_size(mesh) > 1 else None
    if mesh == "auto":
        return grid_mesh(axis=axis)
    if isinstance(mesh, int):
        return grid_mesh(mesh, axis=axis)
    raise TypeError(f"mesh must be None, 'auto', an int device count or a "
                    f"jax.sharding.Mesh; got {type(mesh).__name__}")


def _mesh_size(mesh: Mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= int(mesh.shape[a])
    return n
