"""Logical-axis -> mesh-axis sharding rules (GSPMD style).

Logical axes used across the model zoo:

  batch    activations' batch dim          -> ("pod", "data")
  seq      sequence dim of *caches*        -> "data" (sequence parallelism
           for long-context decode; activations keep seq unsharded)
  vocab    embedding / logits vocab dim    -> "model"
  embed    d_model dim                     -> None (or "data" under FSDP)
  heads    attention heads                 -> "model"
  kv_heads KV heads                        -> "model" when divisible
  mlp      FFN hidden dim                  -> "model"
  expert   MoE expert dim                  -> "model"  (expert parallelism)
  layers   scan-stacked layer dim          -> None
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": ("data",),
    "vocab": ("model",),
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "mla_latent": ("model",),
    "layers": None,
    "conv": None,
    "state": None,
}

# FSDP variant: additionally shard the d_model dim of weights over "data"
FSDP_RULES = dict(DEFAULT_RULES, embed=("data",))


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Maps logical axis names to mesh axes, restricted to a given mesh.

    When a concrete ``shape`` is provided, assignments that don't divide
    the dimension are dropped (rightmost mesh axis first) — e.g. kv_heads=4
    on a model=16 mesh falls back to replication (the standard
    KV-replication strategy for GQA under wide TP)."""

    rules: tuple[tuple[str, tuple[str, ...] | None], ...]
    mesh_axes: tuple[str, ...]
    axis_sizes: tuple[tuple[str, int], ...]

    @classmethod
    def create(cls, mesh: Mesh, overrides: dict | None = None) -> "MeshRules":
        rules = dict(DEFAULT_RULES)
        if overrides:
            rules.update(overrides)
        # Drop mesh axes that don't exist on this mesh (e.g. no "pod").
        clean = {}
        for k, v in rules.items():
            if v is None or v == ():
                clean[k] = None
            else:
                kept = tuple(a for a in v if a in mesh.axis_names)
                clean[k] = kept if kept else None
        shape = mesh.shape  # dict-like on both Mesh and AbstractMesh
        sizes = tuple((a, int(shape[a])) for a in mesh.axis_names)
        return cls(tuple(sorted(clean.items())), tuple(mesh.axis_names), sizes)

    def _lookup(self, logical: str | None):
        if logical is None:
            return None
        for k, v in self.rules:
            if k == logical:
                return v
        return None

    def _size(self, axis: str) -> int:
        for k, v in self.axis_sizes:
            if k == axis:
                return v
        return 1

    def pspec(self, axes: tuple[str | None, ...],
              shape: tuple[int, ...] | None = None) -> P:
        used: set[str] = set()
        parts = []
        for i, a in enumerate(axes):
            m = self._lookup(a)
            if m is None:
                parts.append(None)
                continue
            kept = tuple(x for x in m if x not in used)
            if shape is not None:
                # drop axes (rightmost first) until the dim divides evenly
                dim = shape[i]
                while kept and dim % _prod(self._size(x) for x in kept) != 0:
                    kept = kept[:-1]
            used.update(kept)
            if not kept:
                parts.append(None)
            elif len(kept) == 1:
                parts.append(kept[0])
            else:
                parts.append(kept)
        # strip trailing Nones for tidiness
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)


def _prod(it) -> int:
    n = 1
    for x in it:
        n *= x
    return n


def logical_to_pspec(axes: tuple[str | None, ...], mesh: Mesh,
                     overrides: dict | None = None) -> P:
    return MeshRules.create(mesh, overrides).pspec(axes)


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def shard_tree(tree_specs, mesh: Mesh):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
