from repro.common.pytree import (  # noqa: F401
    ParamDef,
    count_params,
    flatten_with_paths,
    materialize,
    specs_of,
    tree_bytes,
)
from repro.common.sharding import (  # noqa: F401
    MeshRules,
    named_sharding,
    logical_to_pspec,
)
