"""Config dataclasses: model, shapes, mesh, train/serve."""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm | recsys
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # --- block composition -------------------------------------------------
    block_kind: str = "attn"          # attn | mamba2 | rwkv6
    # attention locality pattern, cycled over layers ("l"=local sliding
    # window, "g"=global). gemma2: ("l","g"); gemma3: 5xl + g.
    attn_pattern: tuple[str, ...] = ("g",)
    window: int | None = None

    # --- attention ---------------------------------------------------------
    attn_kind: str = "gqa"            # gqa | mla
    logit_softcap: float | None = None
    final_softcap: float | None = None
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_local: float | None = None

    # --- MLA (deepseek) ----------------------------------------------------
    q_lora_rank: int | None = None
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # --- MoE ---------------------------------------------------------------
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "tp"              # tp | ep_a2a | dense (tiny smoke)
    moe_chunks: int = 1               # token microchunks through the MoE ffn
    router_scale: float = 1.0

    # --- MLP ---------------------------------------------------------------
    mlp_kind: str = "swiglu"          # swiglu | geglu | gelu

    # --- SSM (mamba2) / hybrid (zamba2) -------------------------------------
    ssm_state: int = 64
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    shared_attn_period: int = 0       # zamba2: apply shared attn block every N

    # --- enc-dec (whisper) ---------------------------------------------------
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_len: int = 1500               # fixed encoder memory length for decode

    # --- VLM (paligemma) -----------------------------------------------------
    vlm_prefix_len: int = 0           # image patch tokens; prefix-LM mask

    # --- norms / misc --------------------------------------------------------
    norm_kind: str = "rms"            # rms | layer
    post_norm: bool = False           # gemma2/3 sandwich norms
    tie_embeddings: bool = True
    embed_scale: bool = True          # gemma-style sqrt(d) embedding scale
    param_dtype: Any = "bfloat16"
    # attention blocking for blockwise/flash paths
    block_q: int = 512
    block_k: int = 512
    # int8 KV cache for global-attention decode (beyond-paper §Perf lever:
    # halves the decode memory term; scales stored per (token, kv_head))
    kv_quant_int8: bool = False
    # flash-style custom-VJP attention for training (recomputes probs in
    # the backward; kills the S^2 residual HBM traffic — §Perf lever).
    # Applies to causal global attention without softcap/prefix masks.
    flash_attention: bool = False
    # chunk-parallel RWKV-6 time mixing (0 = token-level lax.scan). §Perf
    # lever: S/Q chunk steps instead of S scan steps in the backward.
    rwkv_chunk: int = 0
    # Megatron-style sequence parallelism: constrain the residual stream's
    # token dim onto the "model" axis between blocks, so TP all-reduces
    # lower to reduce-scatter + all-gather pairs (§Perf lever).
    seq_parallel: bool = False
    # shard batched-decode KV caches on the SEQUENCE dim over "model"
    # (instead of kv_heads): the fit story for archs whose kv_heads <
    # model-axis size (e.g. gemma2's 8 kv heads on a 16-way model axis)
    decode_seq_shard: bool = False
    # optimizer state dtype override (bf16 for the 100B+ MoE cells)
    opt_dtype: str = "float32"

    def layer_kind(self, i: int) -> str:
        """'l' or 'g' for attention layer i."""
        return self.attn_pattern[i % len(self.attn_pattern)]

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode
    # how to shard the KV cache for decode: "batch" (many requests) or
    # "seq" (single huge context -> sequence parallel cache)
    cache_shard: str = "batch"


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatch: int | None = None      # grad accumulation microbatch size
    remat: bool = True
    zero1: bool = True                 # shard optimizer state over data axis
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    seed: int = 0
