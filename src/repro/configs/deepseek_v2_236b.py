"""DeepSeek-V2 236B [arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2].

60L d_model=5120 128H MLA (kv_lora=512, q_lora=1536) vocab=102400;
MoE: 2 shared + 160 routed experts, top-6, expert d_ff=1536; first layer
dense (d_ff=12288).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=12288,               # dense layers
    vocab=102400,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    moe=True,
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1536,
    first_dense_layers=1,
    moe_impl="ep_a2a",
    moe_chunks=8,
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    embed_scale=False,
    opt_dtype="bfloat16",
)


def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, q_lora_rank=32, kv_lora_rank=24,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        n_experts=8, top_k=2, n_shared_experts=2, moe_d_ff=32,
        first_dense_layers=1, moe_impl="dense", moe_chunks=1,
        param_dtype="float32")
