"""PaliGemma-3B [arXiv:2407.07726; hf:google/paligemma-3b-pt-224].

Backbone: gemma-2B decoder — 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216.  SigLIP frontend is a STUB: input_specs() provides 256
precomputed patch embeddings; attention is prefix-LM (bidirectional over
the image prefix, causal over text).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    mlp_kind="geglu",
    vlm_prefix_len=256,
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale=True,
)


def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256, vlm_prefix_len=8, param_dtype="float32")
