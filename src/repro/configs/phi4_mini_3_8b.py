"""Phi-4-mini 3.8B [arXiv:2412.08905; hf:microsoft/Phi-4-mini-instruct].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064, RoPE SwiGLU GQA.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=200064,
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale=False,
)


def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab=256, param_dtype="float32")
