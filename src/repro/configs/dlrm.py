"""DLRM with the paper's Table II parameters (the paper's own workload)."""
import dataclasses

from repro.models.dlrm import DLRMConfig

CONFIG = DLRMConfig()


def smoke():
    return dataclasses.replace(
        CONFIG, n_dense=16, n_tables=4, emb_dim=8, pooling=5,
        rows_per_table=100, bot_mlp=(32, 32), top_mlp=(32, 32))
