"""Zamba2-1.2B [arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B].

38 Mamba2 blocks, d_model=2048, ssm_state=64, + a shared transformer block
(GQA 32H kv=32, d_ff=8192) applied every 6 mamba blocks; vocab=32000.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    block_kind="mamba2",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    ssm_chunk=64,
    shared_attn_period=6,
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale=False,
)


def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, ssm_state=16, ssm_headdim=16, ssm_chunk=8,
        shared_attn_period=2, param_dtype="float32")
