"""Whisper-base [arXiv:2212.04356; openai/whisper].

Enc-dec: 6L+6L d_model=512 8H d_ff=2048 vocab=51865.  The conv frontend is
a STUB: input_specs() provides precomputed frame embeddings (B, T, 512).
Sinusoidal positions, bidirectional encoder, causal decoder + cross-attn.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    n_enc_layers=6,
    enc_dec=True,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab=51865,
    mlp_kind="gelu",
    norm_kind="layer",
    enc_len=1500,
    tie_embeddings=True,
    embed_scale=False,
)


def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=256, enc_len=24, param_dtype="float32")
