"""DeepSeek-V3 671B [arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3].

61L d_model=7168 128H MLA (kv_lora=512, q_lora=1536, rope 64) vocab=129280;
MoE: 1 shared + 256 routed experts, top-8, expert d_ff=2048; first 3 layers
dense (d_ff=18432).  MTP head omitted (training objective detail).
EP via all-to-all dispatch (the paper's A2A traffic); bf16 optimizer state
(DESIGN.md §5 memory note).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,               # dense layers
    vocab=129280,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    moe=True,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    moe_impl="ep_a2a",
    moe_chunks=8,
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    embed_scale=False,
    opt_dtype="bfloat16",
)


def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, q_lora_rank=32, kv_lora_rank=24,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        n_experts=8, top_k=2, moe_d_ff=32, first_dense_layers=1,
        moe_impl="dense", moe_chunks=1, param_dtype="float32")
