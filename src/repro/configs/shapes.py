"""The four assigned input-shape cells + per-arch applicability."""
from __future__ import annotations

from repro.configs.base import ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode",
                         cache_shard="batch")
LONG_500K = ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode",
                        cache_shard="seq")

ALL_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# long_500k requires sub-quadratic attention: run only for SSM / hybrid /
# sliding-window archs (see DESIGN.md §Arch-applicability).
LONG_OK = frozenset({"rwkv6-3b", "zamba2-1.2b", "gemma3-27b", "gemma2-9b"})


def shapes_for(arch: str) -> list[ShapeConfig]:
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if arch in LONG_OK:
        out.append(LONG_500K)
    return out


def skip_reason(arch: str, shape_name: str) -> str | None:
    if shape_name == "long_500k" and arch not in LONG_OK:
        return ("pure full-attention arch: 500k-token decode cache is "
                "quadratic-prefill territory; skipped per brief")
    return None
