"""Gemma-2 9B [arXiv:2408.00118; hf:google/gemma-2-9b].

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000;
alternating local(4096)/global attention, logit softcaps (50 attn / 30
final), sandwich (pre+post) RMSNorm, GeGLU.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    mlp_kind="geglu",
    attn_pattern=("l", "g"),
    window=4096,
    logit_softcap=50.0,
    final_softcap=30.0,
    post_norm=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale=True,
)


def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, window=32, param_dtype="float32")
