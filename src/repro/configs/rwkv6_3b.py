"""RWKV-6 "Finch" 3B [arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b].

32L d_model=2560 (attention-free, 40 heads of 64) d_ff=8960 vocab=65536;
data-dependent decay linear attention.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    block_kind="rwkv6",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab=65536,
    tie_embeddings=False,
    embed_scale=False,
)


def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, param_dtype="float32")
