"""Architecture registry: ``get_config(name)`` / ``get_model(name, mesh)``.

Every assigned architecture is a selectable config (``--arch <id>``); each
file records its public source.  ``smoke_config(name)`` returns a reduced
same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    MeshConfig,
    ModelConfig,
    MULTI_POD,
    ShapeConfig,
    SINGLE_POD,
    TrainConfig,
)

_MODULES = {
    "paligemma-3b": "paligemma_3b",
    "whisper-base": "whisper_base",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "gemma3-27b": "gemma3_27b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "gemma2-9b": "gemma2_9b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "zamba2-1.2b": "zamba2_1_2b",
    "rwkv6-3b": "rwkv6_3b",
    "dlrm": "dlrm",
}

ARCHS = tuple(k for k in _MODULES if k != "dlrm")


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def smoke_config(name: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.smoke()


def get_model(name: str, mesh=None):
    cfg = get_config(name)
    if name == "dlrm":
        from repro.models.dlrm import DLRM
        return DLRM(cfg, mesh)
    from repro.models.model_api import Model
    return Model(cfg, mesh)


def smoke_model(name: str, mesh=None):
    cfg = smoke_config(name)
    if name == "dlrm":
        from repro.models.dlrm import DLRM
        return DLRM(cfg, mesh)
    from repro.models.model_api import Model
    return Model(cfg, mesh)
