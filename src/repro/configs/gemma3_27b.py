"""Gemma-3 27B [hf:google/gemma-3-27b-pt (family: google/gemma-3-1b-pt)].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144;
5:1 local(1024):global pattern, qk-norm, dual rope theta
(10k local / 1M global), 128k context.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    mlp_kind="geglu",
    attn_pattern=("l", "l", "l", "l", "l", "g"),
    window=1024,
    qk_norm=True,
    post_norm=True,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    tie_embeddings=True,
    embed_scale=True,
)


def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=7, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, window=32, param_dtype="float32")
