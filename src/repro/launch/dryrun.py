import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the sharding config is coherent end-to-end
(no sharding mismatch, no unsupported collective, memory accounted) and
captures the roofline inputs:

  * compiled.memory_analysis()  -> bytes/device (does it fit HBM?)
  * compiled.cost_analysis()    -> HLO FLOPs / bytes (compute+memory terms)
  * compiled HLO text           -> per-collective bytes (collective term)

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
  python -m repro.launch.dryrun --all          # every cell, subprocesses
"""
import argparse
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def _shard_tree(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def collective_stats(hlo_text: str) -> dict:
    from repro.core.hlo_comm import extract, summarize
    ops = extract(hlo_text)
    return summarize(ops)


def corrected_totals(hlo_text: str) -> dict:
    """Trip-count-corrected FLOPs/bytes/collectives (scan bodies x trips)."""
    from repro.core.hlo_counter import totals
    t = totals(hlo_text)
    return {"flops": t.flops, "bytes": t.bytes, "bytes_floor": t.bytes_floor,
            "collectives": dict(t.coll)}


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                verbose: bool = True) -> dict:
    from repro.common.pytree import abstract, count_params
    from repro.configs import get_model
    from repro.configs.shapes import ALL_SHAPES, skip_reason
    from repro.launch.mesh import make_production_mesh
    from repro.train.optimizer import init_opt_state, opt_state_specs
    from repro.train.train_step import make_train_step
    from repro.configs.base import TrainConfig

    t0 = time.time()
    shape = ALL_SHAPES[shape_name]
    reason = skip_reason(arch, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = get_model(arch, mesh)
    cfg = model.cfg

    # §Perf A/B knobs: REPRO_OPT=flash,kvquant,gradspec,cap1,tpmoe,chunks4
    opts = set(filter(None, os.environ.get("REPRO_OPT", "").split(",")))
    if opts:
        import dataclasses
        from repro.models.model_api import Model
        repl = {}
        if "flash" in opts:
            repl["flash_attention"] = True
        if "kvquant" in opts:
            repl["kv_quant_int8"] = True
        if "cap1" in opts:
            repl["capacity_factor"] = 1.0
        if "tpmoe" in opts:
            repl["moe_impl"] = "tp"
        if "chunks4" in opts:
            repl["moe_chunks"] = 4
        if "rwkvchunk" in opts:
            repl["rwkv_chunk"] = 32
        if "seqp" in opts:
            repl["seq_parallel"] = True
        if "seqcache" in opts:
            repl["decode_seq_shard"] = True
        if repl:
            model = Model(dataclasses.replace(cfg, **repl), mesh)
            cfg = model.cfg

    p_defs = model.param_defs()
    p_abs = abstract(p_defs)
    p_specs = model.param_specs()
    p_shard = _shard_tree(p_specs, mesh)
    n_params = count_params(p_defs)

    if shape.kind == "train":
        keep_master = jnp.dtype(getattr(cfg, "param_dtype", "float32")) != jnp.float32
        opt_dtype = jnp.dtype(getattr(cfg, "opt_dtype", "float32"))
        opt_abs = jax.eval_shape(
            lambda p: init_opt_state(p, opt_dtype, keep_master), p_abs)
        o_specs = opt_state_specs(p_specs, p_defs, mesh, zero1=True,
                                  keep_master=keep_master)
        o_shard = _shard_tree(o_specs, mesh)
        batch_abs = model.input_specs(shape)
        b_shard = _shard_tree(model.batch_pspecs(shape), mesh)
        # grad-accumulation microbatch sized to keep per-device activation
        # residency bounded (see DESIGN.md §5)
        n_bshard = mesh.devices.size // mesh.shape["model"]
        per_dev = 2 if cfg.d_model >= 5000 else 4
        micro = min(shape.global_batch, per_dev * n_bshard)
        tcfg = TrainConfig(microbatch=micro)
        grad_specs = o_specs["mu"] if "gradspec" in opts else None
        step = make_train_step(model, tcfg, grad_specs=grad_specs)
        fn = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, None))
        args = (p_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        batch_abs = model.input_specs(shape)
        b_shard = _shard_tree(model.batch_pspecs(shape), mesh)
        fn = jax.jit(lambda p, b: model.prefill(p, b),
                     in_shardings=(p_shard, b_shard))
        args = (p_abs, batch_abs)
    else:  # decode
        spec = model.input_specs(shape)
        bspec = model.batch_pspecs(shape)
        cache_abs, tok_abs = spec["cache"], spec["tokens"]
        c_shard = _shard_tree(bspec["cache"], mesh)
        t_shard = _shard_tree(bspec["tokens"], mesh)
        fn = jax.jit(model.decode_step,
                     in_shardings=(p_shard, c_shard, t_shard),
                     out_shardings=(None, c_shard))
        args = (p_abs, cache_abs, tok_abs)

    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    corr = corrected_totals(hlo)
    hlo_dir = os.environ.get("REPRO_HLO_DIR")
    if hlo_dir:  # keep the artifact so metrics can be re-derived w/o recompile
        import gzip
        os.makedirs(hlo_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'mp' if multi_pod else 'sp'}"
        with gzip.open(os.path.join(hlo_dir, tag + ".hlo.gz"), "wt") as f:
            f.write(hlo)
    del hlo

    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
    }
    out = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.devices.size,
        "n_params": n_params,
        "kind": shape.kind,
        "memory": mem_d,
        "flops_raw": cost.get("flops"),
        "bytes_accessed_raw": cost.get("bytes accessed"),
        "transcendentals": cost.get("transcendentals"),
        "collective_bytes_raw": coll,
        # trip-count-corrected (scan bodies x trips) — use THESE for roofline
        "flops": corr["flops"],
        "bytes_accessed": corr["bytes"],
        "bytes_floor": corr["bytes_floor"],
        "collective_bytes": corr["collectives"],
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    if verbose:
        print("memory_analysis:", {k: v for k, v in mem_d.items()})
        print("cost_analysis(raw): flops=%s bytes=%s" % (cost.get("flops"),
                                                         cost.get("bytes accessed")))
        print("corrected: flops=%.3e bytes=%.3e" % (corr["flops"], corr["bytes"]))
        print("collectives:", {k: f"{v:.3e}" for k, v in corr["collectives"].items()})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.all:
        from repro.configs import ARCHS
        from repro.configs.shapes import shapes_for
        os.makedirs("experiments/dryrun", exist_ok=True)
        failures = []
        for arch in ARCHS:
            for shape in shapes_for(arch):
                for mp in (False, True):
                    tag = f"{arch}_{shape.name}_{'mp' if mp else 'sp'}"
                    out = f"experiments/dryrun/{tag}.json"
                    if os.path.exists(out):
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape.name, "--out", out]
                    if mp:
                        cmd.append("--multi-pod")
                    print(">>>", tag, flush=True)
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    if r.returncode != 0:
                        failures.append((tag, r.stderr[-2000:]))
                        print("FAIL", tag, r.stderr[-800:], flush=True)
        print(f"done; {len(failures)} failures")
        sys.exit(1 if failures else 0)

    res = dryrun_cell(args.arch, args.shape, args.multi_pod)
    blob = json.dumps(res, indent=1, default=str)
    print(blob)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob)


if __name__ == "__main__":
    main()
