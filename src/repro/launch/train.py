"""End-to-end training driver (example application entry point).

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 200 --batch 8 --seq 128

--smoke uses the reduced same-family config (CPU-runnable); without it the
full config is built (cluster-scale).  Fault tolerance: checkpoint/restart
via ft.TrainRunner; --fail-at N injects a failure to exercise restart.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_model, smoke_model
from repro.configs.base import TrainConfig
from repro.data.pipeline import dlrm_batch, lm_batch
from repro.ft.fault_tolerance import (FailureInjector, RunnerConfig,
                                      StragglerDetector, TrainRunner)
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--microbatch", type=int, default=None)
    args = ap.parse_args()

    model = smoke_model(args.arch) if args.smoke else get_model(args.arch)
    cfg = model.cfg
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                       total_steps=args.steps, microbatch=args.microbatch)
    params, opt_state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    step_fn = jax.jit(make_train_step(model, tcfg))

    is_dlrm = args.arch == "dlrm"

    def make_batch(step):
        if is_dlrm:
            b = dlrm_batch(0, step, args.batch, cfg)
        else:
            b = lm_batch(0, step, args.batch, args.seq, cfg.vocab)
            if getattr(cfg, "vlm_prefix_len", 0):
                b["img"] = jnp.zeros((args.batch, cfg.vlm_prefix_len, cfg.d_model),
                                     jnp.bfloat16)
            if getattr(cfg, "enc_dec", False):
                b["frames"] = jnp.zeros((args.batch, args.seq, cfg.d_model),
                                        jnp.bfloat16)
        return {k: jnp.asarray(v) for k, v in b.items()}

    runner = TrainRunner(
        RunnerConfig(ckpt_dir=args.ckpt_dir, checkpoint_every=args.ckpt_every),
        step_fn, make_batch,
        injector=FailureInjector((args.fail_at,) if args.fail_at >= 0 else ()),
        straggler=StragglerDetector(),
    )
    params, opt_state = runner.run(params, opt_state, args.steps)
    losses = [m["loss"] for m in runner.metrics_log]
    print(f"steps={len(runner.metrics_log)} restarts={runner.restarts} "
          f"first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f} "
          f"stragglers={len(runner.straggler.flagged)}")


if __name__ == "__main__":
    main()
