"""Batched serving driver: prefill + KV-cache decode over a request queue.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
      --requests 16 --new-tokens 12
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_model, smoke_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    model = smoke_model(args.arch) if args.smoke else get_model(args.arch)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, model.cfg.vocab, args.prompt_len,
                                    dtype=np.int32), args.new_tokens)
            for i in range(args.requests)]
    eng = ServeEngine(model, params, batch_slots=args.slots,
                      max_len=args.prompt_len + args.new_tokens + 8)
    results = eng.run(reqs)
    tput = sum(len(r.tokens) for r in results) / sum(r.latency_s for r in results)
    for r in results[:4]:
        print(f"req {r.rid}: {r.tokens[:8]}... latency={r.latency_s:.2f}s")
    print(f"served {len(results)} requests; decode throughput ~{tput:.1f} tok/s")


if __name__ == "__main__":
    main()
