"""The learned CC policy: a tiny per-flow MLP in the Policy-API-v2 mold.

One hidden layer over six normalized feedback/context features, two
heads computing bounded rate/window *targets* that the per-flow state
tracks at RTT timescale.  Everything rides the existing policy currency:

* weights are flat scalar ``ParamSpec`` entries (``w1_{j}{i}``,
  ``b1_{j}``, ``w2_{o}{j}``, ``b2_{o}``) — the trained weights ARE the
  policy's ``cc_params``, so sweeps, autotune, ``stack_policies`` and the
  engine's traced-params contract all apply unchanged;
* state is a dict of (F,) float32 leaves and the update is pure
  elementwise jnp, so the policy is kernel-eligible
  (``cc.kernel_eligible``) and runs on the fused Pallas engine-step tiles
  like the seven classical policies;
* the loss reaction is a *structural* multiplicative cut outside the net
  (``loss_cut``), so the ``loss_aware`` monotonicity contract holds for
  any weight setting, and the ``jnp.where(loss > 0, ...)`` guard keeps
  lossless runs bitwise-identical to the goldens.

Features (all dimensionless, bounded): ECN mark fraction, squashed
queueing-delay ratio, squashed INT utilisation, current rate / line,
window / BDP (squashed), 1 / schedule fan-in.  The window target is
parametrized *around* the static-window prior (paper §IV-E:
W = margin*BDP/fanin + headroom/fanin) and the rate target around the
line rate, so zero weights recover the static-window policy and the net
learns a modulation of a known-good baseline.

``default_weights()`` loads the committed trained weights
(``mlp_weights.json``, produced by ``scripts/train_mlp_cc.py``) so
``cc.get_policy("mlp")`` is the *trained* policy; a fresh seeded init is
used only when the file is absent.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cc import FlowCtx, ParamSpec, Policy, Signals  # noqa: F401

N_FEATURES = 6
HIDDEN = 4

# head parametrization: the state *tracks* net-computed bounded targets at
# RTT timescale (alpha = dt/rtt) instead of taking a raw multiplicative
# random walk.  A multiplicative update saturates rate/win at the hard
# clip bounds within a few hundred RTTs, and clip's flat region kills the
# gradient — target tracking is a contraction (alpha < 1), so gradients
# flow through the target at every step of the unrolled scan.
_RATE_BIAS = 4.0     # sigmoid(bias) = 0.982: zero weights -> rate ~ line
_WIN_SPAN = 2.5      # win target within e^+-2.5 of the static-window prior

_WEIGHT_BOUND = 8.0


def _weight_names() -> tuple:
    names = []
    for j in range(HIDDEN):
        names += [f"w1_{j}{i}" for i in range(N_FEATURES)] + [f"b1_{j}"]
    for o in range(2):
        names += [f"w2_{o}{j}" for j in range(HIDDEN)] + [f"b2_{o}"]
    return tuple(names)


WEIGHT_KEYS = _weight_names()

_WEIGHTS_PATH = os.path.join(os.path.dirname(__file__), "mlp_weights.json")
_DEFAULT_CACHE: dict = {}


def init_weights(seed: int = 0) -> dict:
    """Deterministic small-Gaussian training init, biased into the
    *binding* regime (rate target ~ line/2, window target well below the
    static-window prior).  The fluid model's ``min()`` delivery dynamics
    make the soft cost exactly flat wherever rate/window have surplus, so
    an init on the plateau sees zero gradient; starting where the outputs
    bind gives the trainer a live gradient toward the pipe-filling
    optimum (lossy scenarios then supply the interior trade-off)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k in WEIGHT_KEYS:
        out[k] = 0.0 if k.startswith("b") else float(rng.normal(0.0, 0.2))
    # rate target sigmoid(-2.5) ~ 0.08*line: below even an 8-way incast's
    # fair share, so the rate head binds in every curriculum scenario
    out["b2_0"] = -(_RATE_BIAS + 2.5)
    out["b2_1"] = -1.0               # win target ~ 0.15x the prior
    return out


def default_weights() -> dict:
    """The committed trained weights (fallback: seeded init)."""
    if "w" not in _DEFAULT_CACHE:
        if os.path.exists(_WEIGHTS_PATH):
            with open(_WEIGHTS_PATH) as f:
                w = {k: float(v) for k, v in json.load(f)["weights"].items()}
            missing = set(WEIGHT_KEYS) - set(w)
            if missing:
                raise ValueError(f"mlp_weights.json is missing {sorted(missing)}"
                                 " — regenerate via scripts/train_mlp_cc.py")
        else:
            w = init_weights(0)
        _DEFAULT_CACHE["w"] = w
    return dict(_DEFAULT_CACHE["w"])


def make_mlp(weights: dict | None = None, out_gain: float = 1.0,
             loss_cut: float = 1.0) -> Policy:
    """The learned policy.  ``weights=None`` loads the committed trained
    weights; pass a dict (e.g. a training checkpoint) to bake others in
    as the spec defaults.  ``out_gain`` scales the target-tracking speed
    (the policy's interpretable key tunable — 0 freezes the state at its
    static-window init); ``loss_cut`` scales the structural lossy-RoCE
    rate/window cut."""
    w = default_weights() if weights is None else dict(weights)
    unknown = set(w) - set(WEIGHT_KEYS)
    if unknown or set(WEIGHT_KEYS) - set(w):
        raise ValueError(f"weights must cover exactly {len(WEIGHT_KEYS)} keys"
                         f" (unknown: {sorted(unknown)})")
    spec = {"out_gain": ParamSpec(float(out_gain), lo=0.0, hi=4.0,
                                  scale="linear"),
            "loss_cut": ParamSpec(float(loss_cut), lo=0.0, hi=4.0,
                                  scale="linear")}
    for k in WEIGHT_KEYS:
        spec[k] = ParamSpec(float(np.clip(w[k], -_WEIGHT_BOUND,
                                          _WEIGHT_BOUND)),
                            lo=-_WEIGHT_BOUND, hi=_WEIGHT_BOUND,
                            scale="linear")

    def init(ctx: FlowCtx):
        f = jnp.maximum(ctx.fanin, 1.0)
        win0 = jnp.maximum(2.0 * ctx.bdp / f + 0.5e6 / f, 4000.0)
        return {"rate": ctx.line * 1.0, "win": win0,
                "bdp": ctx.bdp * 1.0, "fanin": f}

    def update(p, st, sig: Signals):
        line = jnp.maximum(sig.line, 1.0)
        base = jnp.maximum(sig.base_rtt, 1e-7)
        bdp = jnp.maximum(st["bdp"], 1.0)
        qd = jnp.maximum(sig.rtt - sig.base_rtt, 0.0) / base
        u = jnp.maximum(sig.util, 0.0)
        x = (sig.ecn,
             qd / (1.0 + qd),
             u / (1.0 + u),
             st["rate"] / line,
             st["win"] / (st["win"] + 4.0 * bdp),
             1.0 / jnp.maximum(st["fanin"], 1.0))
        h = [jnp.tanh(sum(p[f"w1_{j}{i}"] * x[i] for i in range(N_FEATURES))
                      + p[f"b1_{j}"])
             for j in range(HIDDEN)]
        sr = sum(p[f"w2_0{j}"] * h[j] for j in range(HIDDEN)) + p["b2_0"]
        sw = sum(p[f"w2_1{j}"] * h[j] for j in range(HIDDEN)) + p["b2_1"]
        # bounded targets: rate in (0, line), window within e^+-_WIN_SPAN
        # of the static-window prior (zero weights -> the prior itself)
        f = jnp.maximum(st["fanin"], 1.0)
        win_prior = jnp.maximum(2.0 * bdp / f + 0.5e6 / f, 4000.0)
        rate_tgt = line * jax.nn.sigmoid(sr + _RATE_BIAS)
        win_tgt = win_prior * jnp.exp(_WIN_SPAN * jnp.tanh(sw))
        # exponential tracking at RTT timescale; dt/rtt scaling makes the
        # per-RTT convergence independent of the integrator's step size
        a = jnp.clip(p["out_gain"] * sig.dt / jnp.maximum(base, sig.dt),
                     0.0, 1.0)
        rate = jnp.clip(st["rate"] + a * (rate_tgt - st["rate"]),
                        1e-3 * line, line)
        win = jnp.clip(st["win"] + a * (win_tgt - st["win"]),
                       1000.0, 32.0 * bdp)
        # structural lossy-RoCE cut outside the net: monotone in loss for
        # any weights (the loss_aware contract); guarded where keeps
        # loss==0 bitwise-lossless
        cut = 1.0 - 0.5 * jnp.minimum(2.0 * p["loss_cut"] * sig.loss, 1.0)
        rate = jnp.where(sig.loss > 0,
                         jnp.maximum(rate * cut, 1e-3 * line), rate)
        win = jnp.where(sig.loss > 0, jnp.maximum(win * cut, 1000.0), win)
        st2 = {"rate": rate, "win": win, "bdp": st["bdp"],
               "fanin": st["fanin"]}
        return st2, rate, win

    return Policy("mlp", spec, init, update, kind="mixed", loss_aware=True)
