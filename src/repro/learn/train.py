"""Train the ``mlp`` CC policy end-to-end through the fluid simulator.

The objective is the engine's differentiable soft cost (integral of
undelivered traffic fraction, ``Simulator.soft_cost_fn``) — summed over a
*curriculum* of ``ScenarioSpec``s spanning topologies, fault regimes
(``FaultSpec``) and fabric corners (``FabricParams``), each scenario's
cost ``vmap``-batched over its fabric corners and normalized by its
initial-weights baseline so no single scenario dominates the gradient.

Mechanics (mirroring ``repro.core.autotune`` where the concerns overlap):

* Adam with global-norm gradient clipping, weights projected onto the
  declared ``ParamSpec`` bounds after every step;
* rematerialized backward pass (``soft_cost_fn(remat=True)``) so the
  per-scenario gradient memory is O(chunk + total/chunk) carries rather
  than one per step;
* non-finite guard: a NaN/inf loss or gradient freezes that step (no
  weight/optimizer update) and is recorded in ``history[i]["nonfinite"]``;
* deterministic throughout — seeded numpy init, float64 python-scalar
  optimizer arithmetic — so two same-seed runs produce bitwise-identical
  weights, and checkpoint/resume (JSON round-trip, exact for float64)
  continues bitwise from where a run stopped.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cc as cc_mod
from repro.core.engine import EngineConfig, Simulator, _as_fabric
from repro.core.faults import FaultSpec
from repro.core.scenario import (CollectiveSpec, FabricSpec, IncastSpec,
                                 ScenarioSpec)
from repro.learn.net import WEIGHT_KEYS, init_weights, make_mlp


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 80
    lr: float = 0.05
    clip_norm: float = 1.0          # global grad-norm clip
    seed: int = 0
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    remat: bool = True
    out_gain: float = 1.0           # fixed (non-trained) policy knobs
    loss_cut: float = 1.0


@dataclasses.dataclass
class LearnResult:
    weights: dict                   # trained weight params (floats)
    history: list                   # one record per Adam step
    baselines: dict                 # per-task initial-weights cost
    baseline_loss: float            # normalized total at step 0 (= ~1/task)
    final_loss: float
    wall_s: float = 0.0             # cumulative train wall across resumes


# fabric corners every curriculum scenario is averaged over: the default
# tuning, an aggressive early-marking ECN ramp, and a tight PFC threshold
# (the PR-8 atlas axes in miniature)
DEFAULT_CORNERS = (None,
                   {"kmin": 100e3, "kmax": 400e3},
                   {"xoff": 0.5e6})


def default_engine_cfg() -> EngineConfig:
    """Short-horizon training config (the autotune operating point):
    2.5k steps at 2us resolve the small curriculum fabrics end to end."""
    return EngineConfig(dt=2e-6, max_steps=2500, max_extends=0,
                        queue_stride=0)


def _single(n):
    return FabricSpec(family="single", n_racks=1, nodes_per_rack=1,
                      gpus_per_node=n)


def _clos(n_racks, nodes_per_rack=1):
    return FabricSpec(family="clos", n_racks=n_racks,
                      nodes_per_rack=nodes_per_rack, gpus_per_node=8,
                      oversubscription=2.0)


def curriculum_default() -> list:
    """(spec, weight) pairs: incast (the paper's Fig-3 microbenchmark),
    a CLOS ring all-reduce, and a lossy-RoCE/IRN incast — three regimes
    an optimized-for-training CC must cover."""
    return [
        (ScenarioSpec(_single(8), IncastSpec(7, 2e6), "mlp",
                      name="incast8"), 1.0),
        (ScenarioSpec(_clos(2), CollectiveSpec("ring", 8e6, n_chunks=2),
                      "mlp", name="ring16"), 1.0),
        (ScenarioSpec(_single(8), IncastSpec(7, 2e6), "mlp",
                      fault_spec=FaultSpec.lossy_roce(1e-3, "irn"),
                      name="incast8_lossy_irn"), 0.5),
    ]


def heldout_default() -> list:
    """Held-out ScenarioSpecs: topology scales and a fault regime
    (go-back-N recovery) the default curriculum never sees."""
    return [
        ScenarioSpec(_single(16), IncastSpec(15, 2e6), "mlp",
                     name="heldout_incast16"),
        ScenarioSpec(_clos(2, nodes_per_rack=2),
                     CollectiveSpec("ring", 16e6, n_chunks=2), "mlp",
                     name="heldout_ring32"),
        ScenarioSpec(_single(8), IncastSpec(7, 2e6), "mlp",
                     fault_spec=FaultSpec.lossy_roce(1e-3, "gbn"),
                     name="heldout_incast8_lossy_gbn"),
    ]


@dataclasses.dataclass
class Task:
    """One curriculum entry compiled to a jitted value-and-grad."""
    name: str
    weight: float
    vg: object                      # weights dict -> (cost, grads)


def make_task(spec: ScenarioSpec, weight: float = 1.0,
              engine_cfg: EngineConfig | None = None,
              corners: tuple = DEFAULT_CORNERS, remat: bool = True,
              train_cfg: TrainConfig = TrainConfig()) -> Task:
    """Compile one scenario into ``weights -> (mean-corner cost, grad)``.

    The fabric corners ride one ``vmap`` over the traced ``FabricParams``
    pytree (stacked leaves), so a task costs one compiled simulation
    regardless of corner count.
    """
    engine_cfg = engine_cfg or default_engine_cfg()
    topo, sched, _ = spec.build()
    policy = make_mlp(weights=init_weights(train_cfg.seed),
                      out_gain=train_cfg.out_gain,
                      loss_cut=train_cfg.loss_cut)
    sim = Simulator(topo, sched, policy, engine_cfg,
                    fabric_params=spec.fabric_params,
                    fault_spec=spec.fault_spec)
    cost = sim.soft_cost_fn(remat=remat)
    base_fab = _as_fabric(spec.fabric_params, engine_cfg)
    fabs = [base_fab.replace(**c) if c else base_fab for c in corners]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *fabs)
    base_params = dict(policy.params)

    def loss_fn(wts):
        params = dict(base_params)
        params.update(wts)
        costs = jax.vmap(cost, in_axes=(None, 0))(params, stacked)
        return jnp.mean(costs)

    name = spec.name or f"{topo.name}_{sched.n_flows}f"
    return Task(name=name, weight=float(weight),
                vg=jax.jit(jax.value_and_grad(loss_fn)))


# ---------------------------------------------------------------------------
# checkpointing (JSON: float64 repr round-trips exactly)
# ---------------------------------------------------------------------------

def save_checkpoint(path: str, state: dict) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(state, f, indent=1)


def load_checkpoint(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# the trainer
# ---------------------------------------------------------------------------

def train(cfg: TrainConfig = TrainConfig(), curriculum: list | None = None,
          tasks: list | None = None,
          engine_cfg: EngineConfig | None = None,
          resume: str | dict | None = None,
          checkpoint_path: str | None = None,
          verbose: bool = False) -> LearnResult:
    """Adam on the curriculum's normalized total soft cost.

    ``curriculum`` is a list of ``(ScenarioSpec, weight)`` (default:
    ``curriculum_default()``); ``tasks`` bypasses spec compilation with
    prebuilt ``Task``s (tests inject failure modes this way).  ``resume``
    is a checkpoint path or dict: training continues bitwise from its
    step/optimizer state.  ``checkpoint_path`` saves resumable state
    after every step.
    """
    if tasks is None:
        curriculum = curriculum if curriculum is not None \
            else curriculum_default()
        tasks = [make_task(spec, weight=w, engine_cfg=engine_cfg,
                           remat=cfg.remat, train_cfg=cfg)
                 for spec, w in curriculum]

    if resume is not None:
        ck = load_checkpoint(resume) if isinstance(resume, str) else resume
        if int(ck["seed"]) != cfg.seed:
            raise ValueError(f"checkpoint seed {ck['seed']} != config "
                             f"seed {cfg.seed}")
        wts = {k: float(v) for k, v in ck["weights"].items()}
        m = {k: float(v) for k, v in ck["m"].items()}
        v = {k: float(v) for k, v in ck["v"].items()}
        step0 = int(ck["step"])
        history = list(ck["history"])
        baselines = {k: float(b) for k, b in ck["baselines"].items()}
        wall0 = float(ck.get("wall_s", 0.0))
    else:
        wts = init_weights(cfg.seed)
        m = {k: 0.0 for k in WEIGHT_KEYS}
        v = {k: 0.0 for k in WEIGHT_KEYS}
        step0, history, baselines, wall0 = 0, [], {}, 0.0

    bound = 8.0

    def project(w):
        return {k: float(np.clip(x, -bound, bound)) for k, x in w.items()}

    wts = project(wts)
    t_start = time.time()
    for i in range(step0, cfg.steps):
        per_task, grad = {}, {k: 0.0 for k in WEIGHT_KEYS}
        finite = True
        for task in tasks:
            c, g = task.vg({k: jnp.float32(wts[k]) for k in WEIGHT_KEYS})
            c = float(c)
            per_task[task.name] = c
            if task.name not in baselines:
                # frozen per-task normalizer from the first evaluation
                baselines[task.name] = max(abs(c), 1e-12) \
                    if math.isfinite(c) else 1.0
            scale = task.weight / baselines[task.name]
            finite &= math.isfinite(c)
            for k in WEIGHT_KEYS:
                gk = float(g[k])
                finite &= math.isfinite(gk)
                grad[k] += scale * gk
        total = sum(task.weight * per_task[task.name]
                    / baselines[task.name] for task in tasks)
        gnorm = math.sqrt(sum(x * x for x in grad.values())) \
            if finite else float("nan")
        rec = {"step": i, "loss": total if finite else float("nan"),
               "per_task": per_task, "grad_norm": gnorm,
               "nonfinite": not finite}
        if finite:
            # global-norm clip -> Adam -> projection onto ParamSpec bounds
            cscale = min(1.0, cfg.clip_norm / max(gnorm, 1e-12))
            rec["clipped"] = cscale < 1.0
            t = i + 1
            for k in WEIGHT_KEYS:
                gk = grad[k] * cscale
                m[k] = cfg.beta1 * m[k] + (1 - cfg.beta1) * gk
                v[k] = cfg.beta2 * v[k] + (1 - cfg.beta2) * gk * gk
                mh = m[k] / (1 - cfg.beta1 ** t)
                vh = v[k] / (1 - cfg.beta2 ** t)
                wts[k] = wts[k] - cfg.lr * mh / (math.sqrt(vh) + cfg.eps)
            wts = project(wts)
        # non-finite steps leave weights AND optimizer moments untouched,
        # exactly as autotune freezes its non-finite members
        history.append(rec)
        if verbose:
            print(f"step {i:3d} loss {rec['loss']:.5f} "
                  f"|g| {gnorm:.3g}{' NONFINITE' if not finite else ''}",
                  flush=True)
        if checkpoint_path:
            save_checkpoint(checkpoint_path, {
                "seed": cfg.seed, "step": i + 1, "weights": wts,
                "m": m, "v": v, "history": history,
                "baselines": baselines,
                "wall_s": round(wall0 + time.time() - t_start, 2)})
    wall = wall0 + time.time() - t_start
    fin = [h["loss"] for h in history if math.isfinite(h["loss"])]
    res = LearnResult(weights=dict(wts), history=history,
                      baselines=dict(baselines),
                      baseline_loss=fin[0] if fin else float("nan"),
                      final_loss=fin[-1] if fin else float("nan"),
                      wall_s=round(wall, 2))
    if history:
        history[-1]["wall_s_total"] = round(wall, 2)
    return res


def train_smoke(steps: int = 5) -> dict:
    """Tiny single-scenario training loop for ``bench_engine.py --smoke``:
    returns the loss trajectory and measured steps/s."""
    cfg = TrainConfig(steps=steps, lr=0.08)
    engine_cfg = EngineConfig(dt=2e-6, max_steps=1200, max_extends=0,
                              queue_stride=0)
    spec = ScenarioSpec(_single(8), IncastSpec(7, 1e6), "mlp",
                        name="smoke_incast8")
    task = make_task(spec, engine_cfg=engine_cfg, corners=(None,),
                     remat=True, train_cfg=cfg)
    t0 = time.time()
    res = train(cfg, tasks=[task])
    wall = time.time() - t0
    losses = [h["loss"] for h in res.history]
    return {"steps": steps, "loss_first": losses[0], "loss_last": losses[-1],
            "loss_decreased": bool(losses[-1] < losses[0]),
            "nonfinite_steps": sum(h["nonfinite"] for h in res.history),
            "steps_per_s": round(steps / wall, 3),
            "wall_s": round(wall, 2)}


# ---------------------------------------------------------------------------
# held-out evaluation: the trained policy vs every classical policy
# ---------------------------------------------------------------------------

def heldout_eval(specs: list | None = None, runner=None,
                 engine_cfg: EngineConfig | None = None,
                 cc_overrides: dict | None = None) -> dict:
    """Evaluate the registered ``mlp`` (trained default weights, or
    ``cc_overrides``) against every classical policy on held-out specs
    via ``run_policy_axis`` — one vmapped dispatch per scenario.

    Returns per-scenario completion times plus the acceptance margins:
    ``vs_best_pct`` (mlp over the best classical, negative = mlp faster)
    and ``vs_worst_pct`` (mlp under the worst classical).
    """
    from repro.core.sweep import SweepRunner
    specs = specs if specs is not None else heldout_default()
    engine_cfg = engine_cfg or EngineConfig(dt=2e-6, max_steps=4000,
                                            max_extends=4, queue_stride=0)
    runner = runner or SweepRunner(engine_cfg)
    pols = list(cc_mod.ALL_POLICIES)
    i_mlp = pols.index("mlp")
    overrides = [cc_overrides if p == "mlp" else None for p in pols] \
        if cc_overrides else None
    out = {"scenarios": [], "policies": pols}
    for spec in specs:
        topo, sched, _ = spec.build()
        batch = runner.run_policy_axis(
            topo, sched, pols, cc_overrides=overrides, cfg=engine_cfg,
            fabric_params=spec.fabric_params, fault_spec=spec.fault_spec)
        ct = {p: float(batch.completion_time[j]) for j, p in enumerate(pols)}
        status = batch.lane_status()
        classical = {p: ct[p] for j, p in enumerate(pols)
                     if p != "mlp" and status[j] == "ok"}
        best = min(classical, key=classical.get)
        worst = max(classical, key=classical.get)
        rec = {
            "scenario": spec.name, "completion_ms":
                {p: round(t * 1e3, 4) for p, t in ct.items()},
            "lane_status": {p: status[j] for j, p in enumerate(pols)},
            "best_classical": best, "worst_classical": worst,
            "vs_best_pct": round((ct["mlp"] / classical[best] - 1) * 100, 2),
            "vs_worst_pct": round((ct["mlp"] / classical[worst] - 1) * 100,
                                  2),
            "mlp_ok": status[i_mlp] == "ok",
        }
        rec["within_5pct_of_best"] = rec["vs_best_pct"] <= 5.0
        rec["beats_worst"] = ct["mlp"] < classical[worst]
        out["scenarios"].append(rec)
    out["all_within_5pct_of_best"] = all(r["within_5pct_of_best"]
                                         for r in out["scenarios"])
    out["all_beat_worst"] = all(r["beats_worst"] for r in out["scenarios"])
    return out
