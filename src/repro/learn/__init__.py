"""Learned congestion control: gradient-through-sim training (paper §V).

The paper closes by calling for "an optimized, yet low-overhead,
congestion control scheme based on the characteristics of distributed
training platforms".  This package builds one end to end through the
differentiable fluid simulator:

* ``net``   — a tiny per-flow MLP policy over the engine's ``Signals``
  feedback + normalized ``FlowCtx`` context, its weights flattened into
  the ``ParamSpec`` currency (registered as the 8th policy ``"mlp"`` in
  ``repro.core.cc.REGISTRY``);
* ``train`` — an Adam loop on ``Simulator.soft_cost_fn(remat=True)``
  across a curriculum of ``ScenarioSpec``s (topologies x fault regimes x
  fabric corners), with per-scenario weighting, gradient clipping,
  non-finite guards and checkpoint/resume.
"""
from repro.learn.net import (HIDDEN, N_FEATURES, WEIGHT_KEYS,  # noqa: F401
                             default_weights, init_weights, make_mlp)
from repro.learn.train import (LearnResult, TrainConfig,  # noqa: F401
                               curriculum_default, heldout_default,
                               heldout_eval, load_checkpoint,
                               save_checkpoint, train, train_smoke)
