"""Batched serving engine: prefill + KV-cache decode with request queue.

Synchronized batching v1: requests are grouped into fixed-size batches with
a common (padded) prompt length; one jitted prefill builds the cache, then
jitted decode steps run until every request in the batch hits its stop
length.  Suitable for throughput serving of homogeneous workloads (the
dry-run decode cells model exactly this regime); continuous per-slot
batching is noted as future work in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Result:
    rid: int
    tokens: np.ndarray
    latency_s: float


class ServeEngine:
    def __init__(self, model, params, batch_slots: int = 8, max_len: int = 256,
                 greedy: bool = True):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.greedy = greedy
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
        self._decode = jax.jit(model.decode_step)

    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        # right-align is unnecessary under synchronized batching: all
        # prompts padded to the max length with repeats of the last token.
        L = max(r.prompt.shape[0] for r in reqs)
        out = np.zeros((len(reqs), L), np.int32)
        for i, r in enumerate(reqs):
            out[i, :len(r.prompt)] = r.prompt
            out[i, len(r.prompt):] = r.prompt[-1]
        return out

    def run(self, requests: list[Request]) -> list[Result]:
        results = []
        for i in range(0, len(requests), self.slots):
            group = requests[i:i + self.slots]
            results.extend(self._run_group(group))
        return results

    def _run_group(self, group: list[Request]) -> list[Result]:
        t0 = time.monotonic()
        pad = self.slots - len(group)
        reqs = group + [Request(-1, group[-1].prompt, 0)] * pad
        prompts = self._pad_prompts(reqs)
        batch = {"tokens": jnp.asarray(prompts)}
        cfg = self.model.cfg
        if cfg.vlm_prefix_len:
            batch["img"] = jnp.zeros((len(reqs), cfg.vlm_prefix_len, cfg.d_model),
                                     jnp.bfloat16)
        if cfg.enc_dec:
            batch["frames"] = jnp.zeros((len(reqs), prompts.shape[1], cfg.d_model),
                                        jnp.bfloat16)
        logits, cache = self._prefill(self.params, batch)
        max_new = max(r.max_new_tokens for r in group)
        max_new = min(max_new, self.max_len - prompts.shape[1] - 1)
        toks = [np.asarray(jnp.argmax(logits, -1))]
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, cache, cur)
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(np.asarray(cur[:, 0]))
        gen = np.stack(toks, axis=1)  # (slots, max_new)
        dt = time.monotonic() - t0
        return [Result(r.rid, gen[i, :r.max_new_tokens], dt)
                for i, r in enumerate(group)]
