"""Pipeline parallelism (GPipe-style) over a "stage" mesh axis.

Optional at the production mesh (the dry-run brief fixes the mesh to
(pod, data, model)), but provided as a first-class primitive for clusters
that want PP instead of deeper DP: stages hold disjoint layer slices and
microbatches stream through `lax.ppermute` inside one shard_map — the
collective-permute traffic pattern the network simulator models.

``gpipe(fn, stage_params, x, mesh, ...)`` where
  fn(params_slice, x) -> x          one stage's computation
  stage_params: leaves (n_stages, ...) sharded over "stage"
  x: (n_micro, micro_batch, ...)    microbatched input

Returns the stacked outputs of the LAST stage, in microbatch order.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def gpipe(fn, stage_params, x, mesh, stage_axis: str = "stage"):
    n_stages = int(mesh.shape[stage_axis])
    n_micro = x.shape[0]

    def local(params_loc, x_loc):
        # params_loc: (1, ...) slice for my stage; x_loc: full microbatches
        # (replicated input: stage 0 reads them, others ignore)
        params_my = jax.tree.map(lambda p: p[0], params_loc)
        sid = lax.axis_index(stage_axis)
        ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(x_loc[0])
        outs = jnp.zeros((n_micro,) + x_loc.shape[1:], x_loc.dtype)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when available)
            take = jnp.clip(t, 0, n_micro - 1)
            inject = jnp.where(sid == 0,
                               jnp.where(t < n_micro, 1.0, 0.0), 0.0)
            cur = jnp.where(inject > 0, x_loc[take], buf)
            y = fn(params_my, cur)
            # last stage commits its result for microbatch (t - n_stages + 1)
            out_idx = jnp.clip(t - n_stages + 1, 0, n_micro - 1)
            commit = (sid == n_stages - 1) & (t - n_stages + 1 >= 0)
            outs = lax.cond(
                commit,
                lambda o: lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
                lambda o: o, outs)
            # shift activations downstream
            nxt = lax.ppermute(y, stage_axis,
                               [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outs), None

        (_, outs), _ = lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # only the last stage holds valid outs; broadcast via masked psum
        outs = lax.psum(jnp.where(sid == n_stages - 1, outs, 0.0), stage_axis)
        return outs

    pspec = jax.tree.map(lambda _: P(stage_axis), stage_params)
    return shard_map(local, mesh=mesh,
                     in_specs=(pspec, P()), out_specs=P(),
                     check_rep=False)(stage_params, x)
