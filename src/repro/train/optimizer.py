"""AdamW with mixed-precision master weights and ZeRO-1 state sharding.

Implemented from scratch (no optax dependency):

* params may live in bf16; the optimizer keeps an fp32 (or bf16, per
  config) master copy + moments, and the working params are re-cast from
  the master each step.
* ZeRO-1: optimizer-state PartitionSpecs get the "data" mesh axis added to
  their first shardable dim, so moments/master are sharded across data
  parallelism (the reduce-scatter/all-gather this induces under pjit is
  exactly the ZeRO-1 communication pattern).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import TrainConfig


def lr_schedule(tcfg: TrainConfig, step):
    warm = jnp.minimum(step / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tcfg.warmup_steps) /
                    jnp.maximum(tcfg.total_steps - tcfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)


def init_opt_state(params, opt_dtype=jnp.float32, keep_master: bool = True):
    def zeros_like_t(x):
        return jnp.zeros(x.shape, opt_dtype)

    state = {
        "mu": jax.tree.map(zeros_like_t, params),
        "nu": jax.tree.map(zeros_like_t, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if keep_master:
        state["master"] = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, tcfg: TrainConfig):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = lr_schedule(tcfg, count)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    masters = state.get("master", params)

    def upd(p, g, mu, nu, m):
        g = g.astype(jnp.float32) * clip
        mu2 = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu2 = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
        step_ = (mu2 / bc1) / (jnp.sqrt(nu2 / bc2) + eps)
        m32 = m.astype(jnp.float32)
        m_new = m32 - lr * (step_ + wd * m32)
        return (m_new.astype(p.dtype), mu2.astype(mu.dtype), nu2.astype(nu.dtype),
                m_new)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    flat_m = jax.tree.leaves(masters)
    outs = [upd(*t) for t in zip(flat_p, flat_g, flat_mu, flat_nu, flat_m)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_state = {
        "mu": jax.tree.unflatten(tdef, [o[1] for o in outs]),
        "nu": jax.tree.unflatten(tdef, [o[2] for o in outs]),
        "count": count,
    }
    if "master" in state:
        new_state["master"] = jax.tree.unflatten(tdef, [o[3] for o in outs])
    else:
        new_params = jax.tree.unflatten(tdef, [o[3].astype(p.dtype)
                                               for o, p in zip(outs, flat_p)])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics


# ---------------------------------------------------------------------------
# ZeRO-1 sharding of optimizer state
# ---------------------------------------------------------------------------

def zero1_spec(pspec: P, shape: tuple[int, ...], mesh, axis: str = "data") -> P:
    """Add ``axis`` to the first dim that is unsharded and divisible."""
    if mesh is None or axis not in mesh.axis_names:
        return pspec
    n = mesh.shape[axis]
    used = set()
    for e in pspec:
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            used.add(a)
    if axis in used:
        return pspec
    parts = list(pspec) + [None] * (len(shape) - len(pspec))
    for i, (e, dim) in enumerate(zip(parts, shape)):
        if e is None and dim % n == 0 and dim >= n:
            parts[i] = axis
            return P(*parts)
        # extend an existing sharding tuple if divisible
    return pspec


def opt_state_specs(param_specs, param_defs, mesh, zero1: bool = True,
                    keep_master: bool = True):
    def spec_of(ps, pd):
        if not zero1:
            return ps
        return zero1_spec(ps, pd.shape, mesh)

    moment_specs = jax.tree.map(spec_of, param_specs, param_defs,
                                is_leaf=lambda x: isinstance(x, P))
    out = {"mu": moment_specs, "nu": moment_specs, "count": P()}
    if keep_master:
        out["master"] = moment_specs
    return out
