"""jit-able train step: loss + grad (+accumulation) + AdamW + metrics."""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import TrainConfig
from repro.train.optimizer import adamw_update, init_opt_state


def make_loss_fn(model):
    def loss_fn(params, batch):
        return model.loss(params, batch)
    return loss_fn


def make_train_step(model, tcfg: TrainConfig, grad_specs=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    If tcfg.microbatch is set, the global batch is split into
    global_batch // microbatch accumulation steps via lax.scan (sequential
    grad accumulation, constant memory).

    ``grad_specs``: optional PartitionSpec tree for the gradient-
    accumulation carry.  Constraining the carry to the ZeRO-1 layout makes
    XLA reduce-scatter each microstep's gradients instead of all-reducing
    the full replicated gradient every microstep — the §Perf "sharded grad
    accumulation" optimization."""
    loss_fn = make_loss_fn(model)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def constrain(g):
        if grad_specs is None:
            return g
        return jax.tree.map(lax.with_sharding_constraint, g, grad_specs)

    def train_step(params, opt_state, batch):
        mb = tcfg.microbatch
        bsz = jax.tree.leaves(batch)[0].shape[0]
        if mb and mb < bsz:
            n_acc = bsz // mb
            stacked = jax.tree.map(
                lambda x: x.reshape(n_acc, mb, *x.shape[1:]), batch)

            def acc_fn(carry, micro):
                loss_c, g_c = carry
                loss, g = grads_of(params, micro)
                g_new = jax.tree.map(lambda a, b: a + b / n_acc, g_c, g)
                return (loss_c + loss / n_acc, constrain(g_new)), None

            zero_g = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = lax.scan(acc_fn, (jnp.zeros((), jnp.float32), zero_g),
                                        stacked)
        else:
            loss, grads = grads_of(params, batch)

        params, opt_state, metrics = adamw_update(params, grads, opt_state, tcfg)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def init_train_state(model, key, tcfg: TrainConfig):
    params = model.init(key)
    keep_master = jnp.dtype(model.cfg.param_dtype) != jnp.float32
    opt_dtype = jnp.dtype(getattr(model.cfg, "opt_dtype", "float32"))
    opt_state = init_opt_state(params, opt_dtype, keep_master)
    return params, opt_state
