"""Deterministic synthetic data pipelines with background prefetch.

Every batch is a pure function of (seed, step) so restarts reproduce the
exact stream (required for checkpoint/restart equivalence tests), and each
host materializes only its local shard before `jax.device_put` assembles
the global array (multi-host pattern; degenerates gracefully on 1 process).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np


def lm_batch(seed: int, step: int, global_batch: int, seq: int, vocab: int) -> dict:
    rng = np.random.default_rng(np.uint64(seed) * np.uint64(1_000_003) + np.uint64(step))
    toks = rng.integers(0, vocab, (global_batch, seq), dtype=np.int32)
    # inject learnable structure: token t+1 correlates with token t
    toks[:, 1::2] = (toks[:, 0::2] * 31 + 7) % vocab
    return {"tokens": toks}


def dlrm_batch(seed: int, step: int, global_batch: int, cfg) -> dict:
    rng = np.random.default_rng(np.uint64(seed) * np.uint64(999_983) + np.uint64(step))
    dense = rng.normal(size=(global_batch, cfg.n_dense)).astype(np.float32)
    idx = rng.integers(0, cfg.rows_per_table,
                       (global_batch, cfg.n_tables, cfg.pooling), dtype=np.int32)
    # clickthrough depends on a dense projection -> learnable
    w = np.asarray(np.sin(np.arange(cfg.n_dense)), np.float32)
    label = (dense @ w > 0).astype(np.float32)
    return {"dense": dense, "sparse_idx": idx, "label": label}


def shard_batch(batch: dict, mesh, pspecs: dict) -> dict:
    """Host numpy batch -> sharded global jax arrays."""
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    return {
        k: jax.device_put(v, NamedSharding(mesh, pspecs[k]))
        for k, v in batch.items()
    }


class Prefetcher:
    """Background-thread prefetch of the (deterministic) batch stream."""

    def __init__(self, make_batch: Callable[[int], dict], start_step: int = 0,
                 depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self._make(step), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
